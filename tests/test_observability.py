"""Observability layer tests: metrics registry, stats edge cases,
lifecycle traces, Chrome trace export/validation, SLO scoring, and the
engine integration.

The acceptance-critical properties pinned here:
  * zero-denominator safety — a fresh engine reports 0.0 rates and a
    None ``prefix_hit_rate``, never a division crash;
  * registry label views sum exactly to their totals;
  * ``EngineStats.reset`` is dataclass-field-driven (every field,
    including dict-valued ones, returns to its declared default);
  * trace-derived TTFT/latency EQUAL the request-timestamp ground truth
    (two-clock design: lifecycle events are recorded on the engine
    clock);
  * the exported tick timeline is valid Chrome Trace Event JSON.
"""
import json
import math

import jax
import numpy as np
import pytest

from repro.configs.base import get_model_config, reduced
from repro.models import api
from repro.serving import Engine, EngineConfig
from repro.serving.observability import (ADMIT, FINISH, PREEMPT, SUBMIT,
                                         TICK_PHASES, TOKEN, Counter,
                                         EngineStats, Histogram,
                                         MetricsRegistry, RequestTrace,
                                         RequestTracer, SLOClass, SLOTracker,
                                         Telemetry, TickTimeline,
                                         parse_slo_class, percentile,
                                         percentile_or_none,
                                         validate_chrome_trace)


# ---------------------------------------------------------------------------
# percentile helpers (the deduplicated serve.py/serving_bench.py helpers)
# ---------------------------------------------------------------------------
def test_percentile_matches_numpy_and_handles_empty():
    xs = [3.0, 1.0, 2.0, 5.0, 4.0]
    assert percentile(xs, 50) == 3.0
    assert percentile(xs, 99) == pytest.approx(np.percentile(xs, 99))
    assert math.isnan(percentile([], 50))
    assert percentile_or_none([], 50) is None
    assert percentile_or_none(xs, 50) == 3.0
    assert percentile_or_none([1.23456789], 50) == 1.2346  # rounded for JSON


# ---------------------------------------------------------------------------
# registry: counters / gauges / histograms with per-label views
# ---------------------------------------------------------------------------
def test_counter_label_views_sum_exactly_to_total():
    c = Counter("tokens")
    rng = np.random.default_rng(0)
    total = 0
    for _ in range(200):
        n = int(rng.integers(1, 9))
        c.inc(n, label=int(rng.integers(0, 4)))
        total += n
    assert c.value == total
    assert sum(c.view().values()) == c.value     # the labels-sum invariant
    assert set(c.view()) == {0, 1, 2, 3}


def test_histogram_label_views_sum_exactly_to_total():
    h = Histogram("lat")
    rng = np.random.default_rng(1)
    for _ in range(300):
        h.observe(float(rng.uniform(0.01, 2.0)),
                  label="interactive" if rng.uniform() < 0.5 else "batch")
    views = h.view()
    assert sum(v.count for v in views.values()) == h.count == 300
    assert sum(v.sum for v in views.values()) == pytest.approx(h.sum)


def test_histogram_quantiles_bounded_relative_error():
    h = Histogram("s")
    rng = np.random.default_rng(2)
    xs = rng.lognormal(mean=-2.0, sigma=1.0, size=5000)
    for x in xs:
        h.observe(float(x))
    for q in (0.50, 0.90, 0.99):
        exact = float(np.quantile(xs, q))
        approx = h.quantile(q)
        assert approx == pytest.approx(exact, rel=0.08)   # ~growth-1 error
    assert h.min == pytest.approx(xs.min())
    assert h.max == pytest.approx(xs.max())
    assert h.quantile(0.0) >= h.min
    assert h.quantile(1.0) <= h.max


def test_histogram_empty_and_reset():
    h = Histogram("x")
    assert h.quantile(0.5) is None and h.mean is None
    assert h.summary()["p50"] is None and h.summary()["count"] == 0
    h.observe(1.0, label="a")
    h.reset()
    assert h.count == 0 and h.view() == {}


def test_registry_get_or_create_and_kind_mismatch():
    r = MetricsRegistry()
    c = r.counter("n")
    assert r.counter("n") is c                   # get-or-create
    with pytest.raises(TypeError, match="already registered"):
        r.gauge("n")
    r.gauge("g").set_max(2.0)
    r.gauge("g").set_max(1.0)                    # peak keeps the max
    assert r.get("g").value == 2.0
    # the label-overflow warning counter is auto-registered as the sink
    # every capped metric reports folds into
    assert r.names() == ["g", MetricsRegistry.OVERFLOW_COUNTER, "n"]
    snap = r.snapshot()
    assert snap["n"]["type"] == "counter"
    r.reset()
    assert r.get("g").value == 0.0


# ---------------------------------------------------------------------------
# EngineStats: zero denominators + dataclass-field-driven reset
# ---------------------------------------------------------------------------
def test_fresh_stats_rates_are_safe_at_zero_denominators():
    s = EngineStats()
    assert s.cobatch_ratio == 0.0                # 0 non-empty ticks
    assert s.accept_rate == 0.0                  # 0 drafted
    assert s.accepted_tok_per_tick == 0.0        # 0 speculating slot-ticks
    assert s.prefix_hit_rate is None             # nothing cache-eligible


def test_prefix_hit_rate_none_only_when_nothing_eligible():
    s = EngineStats()
    s.cache_eligible_tokens = 10
    assert s.prefix_hit_rate == 0.0              # eligible but all missed
    s.cache_hit_tokens = 5
    assert s.prefix_hit_rate == 0.5


def test_stats_reset_is_field_driven():
    s = EngineStats()
    # dirty EVERY field, dict-valued ones included — a counter added
    # tomorrow is covered by construction, not by this list
    import dataclasses
    for f in dataclasses.fields(s):
        if f.default_factory is not dataclasses.MISSING:
            getattr(s, f.name)[0] = 7
        elif isinstance(f.default, float):
            setattr(s, f.name, 0.9)
        else:
            setattr(s, f.name, 13)
    assert s.as_dict() != EngineStats().as_dict()
    s.reset()
    assert s.as_dict() == EngineStats().as_dict()
    # dict fields are fresh objects, not shared defaults
    s.tokens_by_submodel[1] = 1
    assert EngineStats().tokens_by_submodel == {}


# ---------------------------------------------------------------------------
# SLO classes + tracker
# ---------------------------------------------------------------------------
def test_parse_slo_class_forms():
    c = parse_slo_class("interactive:0.5:5")
    assert c == SLOClass("interactive", 0.5, 5.0)
    assert parse_slo_class("batch:-:60") == SLOClass("batch", None, 60.0)
    assert parse_slo_class("loose") == SLOClass("loose", None, None)
    assert parse_slo_class("x:0.25") == SLOClass("x", 0.25, None)
    for bad in (":1:2", "a:b:c", "a:1:2:3", "a:-1:2", "a:inf:2"):
        with pytest.raises(ValueError):
            parse_slo_class(bad)


def test_slo_meets_semantics():
    c = SLOClass("i", ttft_s=0.5, latency_s=5.0)
    assert c.meets(0.5, 5.0)                     # bounds are inclusive
    assert not c.meets(0.6, 1.0)
    assert not c.meets(0.1, 6.0)
    assert not c.meets(None, 1.0)                # missing measurement fails
    assert SLOClass("free").meets(None, None)    # unbounded always holds


def test_slo_tracker_attainment_and_report():
    t = SLOTracker([SLOClass("i", 0.5, 5.0)])
    assert t.attainment("i") is None             # nothing scored yet
    assert t.observe("i", 0.2, 2.0) is True
    assert t.observe("i", 0.9, 2.0) is False     # ttft violation
    assert t.observe("i", 0.2, 9.0) is False     # latency violation
    assert t.observe("unseen", 99.0, 99.0) is True   # unconfigured class
    rep = t.report()
    assert rep["i"]["attainment"] == pytest.approx(1 / 3)
    assert rep["i"]["ttft_violations"] == 1
    assert rep["i"]["latency_violations"] == 1
    assert rep["unseen"]["ttft_target_s"] is None
    t.reset()
    assert t.report() == {}


# ---------------------------------------------------------------------------
# lifecycle traces
# ---------------------------------------------------------------------------
def test_request_trace_derived_metrics():
    tr = RequestTrace(7)
    tr.add(SUBMIT, 0.0)
    tr.add(ADMIT, 1.0, slot=0, cached=0)
    tr.add(TOKEN, 2.5, n=1)
    tr.add(PREEMPT, 3.0)
    tr.add(ADMIT, 4.5, slot=1, cached=0)         # re-admission
    tr.add(TOKEN, 5.0, n=3)
    tr.add(FINISH, 6.0, tokens=4)
    assert tr.ttft_s == 2.5
    assert tr.latency_s == 6.0
    assert tr.queue_s == 1.0                     # submit -> FIRST admit
    assert tr.preempt_wait_s == 1.5              # 3.0 -> 4.5
    assert tr.num_preemptions == 1
    assert tr.committed_tokens == 4


def test_tracer_ring_and_finish_transition():
    t = RequestTracer(maxlen=2)
    for rid in range(3):
        t.record(rid, SUBMIT, float(rid))
        assert t.live[rid].req_id == rid
        t.record(rid, FINISH, float(rid) + 1)
        assert rid not in t.live                 # finish retires the trace
    assert [tr.req_id for tr in t.finished] == [1, 2]   # ring dropped 0
    assert t.get(2).latency_s == 1.0
    t.clear()
    assert t.num_events == 0


# ---------------------------------------------------------------------------
# tick timeline -> Chrome Trace Event JSON
# ---------------------------------------------------------------------------
def _demo_timeline():
    tl = TickTimeline()
    t = 1000.0
    for tick in range(3):
        marks = [t, t + .001, t + .002, t + .010, t + .011]
        tl.add_tick(tick, marks,
                    slot_events=[(0, "decode", t + .002, t + .010,
                                  {"req": tick, "tokens": 1})],
                    extra_spans=[("draft", t + .001, t + .0015)],
                    counters={"used_pages": 4 + tick})
        t += 0.02
    tl.instant("preempt", t, req=9)
    return tl


def test_timeline_chrome_export_is_valid(tmp_path):
    tl = _demo_timeline()
    doc = tl.to_chrome()
    n = validate_chrome_trace(doc)
    assert n == tl.num_events + 3                # + process/thread metadata
    ev = doc["traceEvents"]
    engine_spans = [e for e in ev if e["ph"] == "X" and e["tid"] == 0]
    assert {e["name"] for e in engine_spans} \
        == set(TICK_PHASES) | {"draft"}
    assert any(e["ph"] == "C" for e in ev)       # counter track
    assert any(e["ph"] == "i" for e in ev)       # instants
    # slot 0 renders on tid 1 with a thread_name record
    names = {(e["tid"], e["args"]["name"]) for e in ev if e["ph"] == "M"
             and e["name"] == "thread_name"}
    assert (1, "slot 0") in names and (0, "engine phases") in names
    # timestamps are rebased to zero and non-negative
    assert min(e["ts"] for e in ev if "ts" in e) == 0.0
    path = tmp_path / "trace.json"
    assert tl.export(str(path)) == len(ev)
    validate_chrome_trace(json.loads(path.read_text()))


def test_validate_chrome_trace_rejects_bad_docs():
    with pytest.raises(ValueError, match="traceEvents"):
        validate_chrome_trace({"events": []})
    with pytest.raises(ValueError, match="non-empty"):
        validate_chrome_trace({"traceEvents": []})
    good = {"ph": "X", "pid": 0, "tid": 0, "name": "x", "ts": 0.0,
            "dur": 1.0}
    validate_chrome_trace({"traceEvents": [good]})
    for corrupt in (dict(good, ph="Z"), dict(good, name=""),
                    dict(good, dur=-1.0), dict(good, pid="zero"),
                    {k: v for k, v in good.items() if k != "ts"}):
        with pytest.raises(ValueError):
            validate_chrome_trace({"traceEvents": [good, corrupt]})


def test_timeline_rejects_wrong_mark_count():
    with pytest.raises(ValueError, match="marks"):
        TickTimeline().add_tick(0, [0.0, 1.0])


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def tiny():
    cfg = reduced(get_model_config("qwen3-1.7b"), dtype="float32")
    return cfg, api.model_init(jax.random.key(0), cfg)


def _drive(engine, reqs, **submit_kw):
    """Deterministic virtual clock: tick i happens at t = i + 1."""
    for prompt, gen in reqs:
        engine.submit(prompt, gen, arrival_time=0.0, **submit_kw)
    t = [0.0]

    def clock():
        t[0] += 1.0
        return t[0]

    engine.run(clock=clock)


def test_engine_traces_match_request_timestamps_exactly(tiny, tmp_path):
    cfg, params = tiny
    obs = Telemetry(timeline=True,
                    slo_classes=[parse_slo_class("default:3:50")])
    engine = Engine(cfg, params,
                    EngineConfig(num_slots=3, num_pages=64, page_size=8,
                                 max_prompt_len=32, max_new_tokens=5,
                                 token_budget=32, policy="on_demand",
                                 kv_dtype="float32",
                                 compute_dtype="float32"),
                    telemetry=obs)
    rng = np.random.default_rng(5)
    reqs = [(rng.integers(1, cfg.vocab_size, (n,)).astype(np.int32), 4)
            for n in (20, 9, 14, 6)]
    _drive(engine, reqs)

    finished = engine.sched.finished
    assert len(finished) == 4
    for req in finished:
        tr = obs.tracer.get(req.id)
        # THE acceptance criterion: trace-derived latency metrics equal
        # the scheduler's own timestamps, exactly — same clock, same
        # values, derived instead of hand-computed
        assert tr.ttft_s == req.t_first_token - req.arrival_time
        assert tr.latency_s == req.t_done - req.arrival_time
        assert tr.queue_s == req.t_admitted - req.arrival_time
        assert tr.committed_tokens == len(req.out_tokens)
        assert tr.prefill_tokens + tr.cached_tokens >= req.prompt_len - 1
        kinds = [e.kind for e in tr.events]
        assert kinds[0] == SUBMIT and kinds[-1] == FINISH

    # streaming histograms saw exactly the finished requests, labeled
    m = engine.metrics()
    lat = m["latency"]["latency_s"]
    assert lat["count"] == 4 and "default" in lat["by_label"]
    ttfts = sorted(r.t_first_token - r.arrival_time for r in finished)
    assert obs.ttft_s.count == 4
    assert obs.ttft_s.min == ttfts[0] and obs.ttft_s.max == ttfts[-1]
    # registry gauges mirror the engine counters after collect()
    assert m["counters"]["generated_tokens"] == engine.generated_tokens
    assert obs.registry.get("generated_tokens").value \
        == engine.generated_tokens
    assert m["slo"]["default"]["finished"] == 4

    # the exported timeline is schema-valid and covers every tick
    path = tmp_path / "tick_trace.json"
    engine.obs.timeline.export(str(path))
    doc = json.loads(path.read_text())
    validate_chrome_trace(doc)
    device_spans = [e for e in doc["traceEvents"]
                    if e["ph"] == "X" and e["name"] == "device_step"]
    assert len(device_spans) == engine.steps

    # reset_stats clears telemetry along with the counters
    engine.reset_stats()
    assert engine.steps == 0 and engine.stats.steps == 0
    assert obs.ttft_s.count == 0 and obs.tracer.num_events == 0
    assert obs.timeline.num_events == 0 and obs.slo.report() == {}


def test_engine_stats_attribute_shim(tiny):
    cfg, params = tiny
    engine = Engine(cfg, params,
                    EngineConfig(num_slots=2, num_pages=32, page_size=8,
                                 max_prompt_len=16, max_new_tokens=4,
                                 token_budget=16, kv_dtype="float32",
                                 compute_dtype="float32"))
    # fresh engine: rates are safe, hit rate is None (nothing eligible)
    assert engine.cobatch_ratio == 0.0
    assert engine.accept_rate == 0.0
    assert engine.accepted_tok_per_tick == 0.0
    assert engine.prefix_hit_rate is None
    # counters stay plain attributes, shimmed onto the stats dataclass
    engine.generated_tokens += 3
    assert engine.stats.generated_tokens == 3
    engine.tokens_by_submodel[1] = 5
    assert engine.stats.tokens_by_submodel == {1: 5}
    engine.reset_stats()
    assert engine.generated_tokens == 0


def test_engine_preemption_emits_trace_events(tiny):
    cfg, params = tiny
    engine = Engine(cfg, params,
                    EngineConfig(num_slots=2, num_pages=10, page_size=4,
                                 max_prompt_len=16, max_new_tokens=5,
                                 token_budget=16, policy="on_demand",
                                 kv_dtype="float32",
                                 compute_dtype="float32"))
    rng = np.random.default_rng(6)
    reqs = [(rng.integers(1, cfg.vocab_size, (15,)).astype(np.int32), 5)
            for _ in range(2)]
    _drive(engine, reqs)
    assert engine.preemptions > 0                # the squeeze actually bit
    preempted = [r for r in engine.sched.finished if r.num_preemptions]
    assert preempted
    for req in preempted:
        tr = engine.obs.tracer.get(req.id)
        assert tr.num_preemptions == req.num_preemptions
        assert req.t_preempted is not None
        assert tr.preempt_wait_s > 0             # preempt -> re-admit gap
        # the re-prefill after preemption is visible as extra chunks
        assert tr.of_kind(PREEMPT)
    # preempt_wait histogram observed the preempted leaders
    assert engine.obs.preempt_wait_s.count == len(preempted)
