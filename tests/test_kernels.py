"""Per-kernel allclose sweeps: pallas_call(interpret=True) vs ref.py oracles,
over shapes and dtypes, plus integration of the kernels into the model paths.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.dropout_matmul.kernel import dropout_matmul
from repro.kernels.dropout_matmul.ref import dropout_matmul_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd.kernel import ssd_chunk_scan
from repro.kernels.ssd.ref import ssd_ref


# ---------------------------------------------------------------------------
# dropout_matmul
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("G,M,K,N,bn", [
    (1, 128, 128, 128, 128),
    (2, 256, 128, 512, 128),
    (4, 128, 256, 256, 64),
    (3, 128, 384, 640, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dropout_matmul_sweep(G, M, K, N, bn, dtype):
    rng = np.random.default_rng(hash((G, M, K, N)) % 2**31)
    x = jnp.asarray(rng.normal(size=(G, M, K)), dtype)
    w = jnp.asarray(rng.normal(size=(K, N)), dtype)
    mask = jnp.asarray(rng.choice([0.0, 2.0], size=(G, N // bn)), jnp.float32)
    out = dropout_matmul(x, w, mask, block_n=bn, interpret=True)
    ref = dropout_matmul_ref(x, w, mask, block_n=bn)
    tol = 1e-4 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=tol * K ** 0.5, rtol=tol)


def test_dropout_matmul_all_dropped_block_is_zero():
    x = jnp.ones((1, 128, 128), jnp.float32)
    w = jnp.ones((128, 256), jnp.float32)
    mask = jnp.asarray([[0.0, 2.0]], jnp.float32)
    out = np.asarray(dropout_matmul(x, w, mask, block_n=128, interpret=True))
    assert (out[:, :, :128] == 0).all()
    assert (out[:, :, 128:] == 2 * 128).all()


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,H,KH,S,D", [
    (1, 2, 2, 128, 64),     # MHA
    (2, 4, 2, 256, 64),     # GQA
    (1, 8, 1, 128, 128),    # MQA
])
@pytest.mark.parametrize("variant", ["causal", "window", "softcap", "full"])
def test_flash_attention_sweep(B, H, KH, S, D, variant):
    rng = np.random.default_rng(hash((B, H, S, variant)) % 2**31)
    q = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, KH, S, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, KH, S, D)), jnp.float32)
    kw = dict(causal=True)
    if variant == "window":
        kw = dict(causal=True, window=64)
    elif variant == "softcap":
        kw = dict(causal=True, softcap=50.0)
    elif variant == "full":
        kw = dict(causal=False)
    out = flash_attention(q, k, v, scale=D ** -0.5, block_q=64, block_k=64,
                          interpret=True, **kw)
    ref = attention_ref(q, k, v, scale=D ** -0.5, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-5)


def test_flash_attention_bf16():
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    out = flash_attention(q, k, v, scale=0.125, block_q=64, block_k=64,
                          interpret=True)
    ref = attention_ref(q, k, v, scale=0.125)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=0.05)


# ---------------------------------------------------------------------------
# SSD chunk scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 64, 2, 16, 16, 16),
    (2, 128, 3, 16, 32, 32),
    (1, 256, 1, 32, 64, 64),
])
def test_ssd_kernel_sweep(B, S, H, P, N, chunk):
    rng = np.random.default_rng(hash((B, S, H, P, N)) % 2**31)
    x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32) * 0.5
    dt = jnp.asarray(np.abs(rng.normal(size=(B, S, H))) + 0.1, jnp.float32)
    A = -jnp.asarray(np.abs(rng.normal(size=(H,))) + 0.5, jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32) * 0.5
    Cm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32) * 0.5
    out = ssd_chunk_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    ref, _ = ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-4, rtol=1e-3)


def test_model_ssd_chunked_matches_sequential_ref():
    """The model's pure-jnp chunked SSD == exact sequential recurrence."""
    from repro.models.ssm import ssd_chunked
    rng = np.random.default_rng(3)
    B, S, H, P, N = 2, 96, 2, 8, 16
    x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32) * 0.5
    dt = jnp.asarray(np.abs(rng.normal(size=(B, S, H))) + 0.1, jnp.float32)
    A = -jnp.asarray(np.abs(rng.normal(size=(H,))) + 0.5, jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32) * 0.5
    Cm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32) * 0.5
    y, final = ssd_chunked(x, dt, A, Bm, Cm, chunk=32)
    yref, fref = ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                               atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(final), np.asarray(fref),
                               atol=2e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# model-path integration (interpret backend)
# ---------------------------------------------------------------------------
def test_mlp_kernel_path_matches_dense_mask():
    """mlp_apply(mask_blocks=...) via the Pallas kernel == dense masked path."""
    from repro.configs.base import get_model_config, reduced
    from repro.core.steps import make_ctx
    from repro.models.layers import mlp_apply, mlp_specs
    from repro.models.params import init_params
    from repro.kernels import backend as KB

    cfg = reduced(get_model_config("qwen3-1.7b"), d_ff=256, d_model=64)
    ctx = make_ctx(cfg, None)
    params = init_params(jax.random.key(0), mlp_specs(cfg))
    G, B, S = 2, 4, 8
    nb = 2
    x = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model), jnp.float32)
    blocks = jnp.asarray([[0.0, 2.0], [2.0, 0.0]], jnp.float32)  # [G, nb]
    dense_mask = jnp.repeat(jnp.repeat(blocks, cfg.d_ff // nb, -1),
                            B // G, 0)[:, None, :]
    ref = mlp_apply(params, x, cfg, ctx, hidden_mask=dense_mask)
    old = KB.get_backend()
    KB.set_backend("interpret")
    try:
        out = mlp_apply(params, x, cfg, ctx, mask_blocks=blocks)
    finally:
        KB.set_backend(old)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_attention_kernel_path_matches_ref_model():
    """attn_apply with interpret backend == ref backend (same params/input)."""
    from repro.configs.base import get_model_config, reduced
    from repro.core.steps import make_ctx
    from repro.models.attention import attn_apply, attn_specs
    from repro.models.params import init_params
    from repro.kernels import backend as KB

    cfg = reduced(get_model_config("qwen3-1.7b"), d_model=64, head_dim=16)
    ctx = make_ctx(cfg, None)
    params = init_params(jax.random.key(0), attn_specs(cfg))
    x = jax.random.normal(jax.random.key(1), (2, 64, cfg.d_model), jnp.float32)
    old = KB.get_backend()
    KB.set_backend("ref")
    try:
        ref, _ = attn_apply(params, x, cfg, ctx)
        KB.set_backend("interpret")
        out, _ = attn_apply(params, x, cfg, ctx)
    finally:
        KB.set_backend(old)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# dimension_semantics: megacore partitioning must not change numerics
# ---------------------------------------------------------------------------
def _strip_compiler_params(module, jitted):
    """Re-trace ``jitted`` with TPUCompilerParams neutralized, restoring the
    module and jit cache afterwards — the with/without outputs must match
    bitwise (dimension_semantics only licenses megacore partitioning; it
    never reorders the per-step op sequence)."""
    import contextlib

    @contextlib.contextmanager
    def ctx():
        orig = module.pltpu.TPUCompilerParams
        module.pltpu.TPUCompilerParams = lambda **kw: None
        jitted.clear_cache()
        try:
            yield
        finally:
            module.pltpu.TPUCompilerParams = orig
            jitted.clear_cache()

    return ctx()


def test_flash_attention_dimension_semantics_no_numeric_change():
    import repro.kernels.flash_attention.kernel as FK

    rng = np.random.default_rng(13)
    q = jnp.asarray(rng.normal(size=(2, 4, 128, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 2, 128, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 2, 128, 64)), jnp.float32)
    kw = dict(scale=0.125, window=48, block_q=64, block_k=64, interpret=True)
    with_sem = FK.flash_attention(q, k, v, **kw)
    with _strip_compiler_params(FK, FK.flash_attention):
        without = FK.flash_attention(q, k, v, **kw)
    assert np.array_equal(np.asarray(with_sem), np.asarray(without))


def test_dropout_matmul_dimension_semantics_no_numeric_change():
    import repro.kernels.dropout_matmul.kernel as DK

    rng = np.random.default_rng(17)
    x = jnp.asarray(rng.normal(size=(2, 128, 256)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)
    mask = jnp.asarray(rng.choice([0.0, 2.0], size=(2, 2)), jnp.float32)
    with_sem = DK.dropout_matmul(x, w, mask, block_n=128, interpret=True)
    with _strip_compiler_params(DK, DK.dropout_matmul):
        without = DK.dropout_matmul(x, w, mask, block_n=128, interpret=True)
    assert np.array_equal(np.asarray(with_sem), np.asarray(without))
