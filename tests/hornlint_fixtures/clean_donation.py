"""Compliant twin of violation_donation.py — hornlint MUST stay silent."""
from functools import partial

import jax
import jax.experimental.pallas as pl
import jax.numpy as jnp


def _step(state, batch):
    return state + batch


def rebind_idiom(state, batch):
    step = jax.jit(_step, donate_argnums=(0,))
    state = step(state, batch)                        # rebinding is clean
    return state


def metadata_after_donate(state, batch):
    step = jax.jit(_step, donate_argnums=(0,))
    new_state = step(state, batch)
    assert new_state.shape == state.shape             # metadata reads allowed
    return new_state


def loop_with_rebind(state, batches):
    @partial(jax.jit, donate_argnums=(0,))
    def tick(s, b):
        return s + b

    for b in batches:
        state = tick(state, b)
    return state


def _alias_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def alias_in_range(x):
    return pl.pallas_call(
        _alias_kernel,
        grid=(4,),
        in_specs=[pl.BlockSpec((8,), lambda i: (i,))],
        out_specs=pl.BlockSpec((8,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((32,), jnp.float32),
        input_output_aliases={0: 0},
    )(x)
