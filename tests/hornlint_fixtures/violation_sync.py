"""Seeded HL2xx violations — hornlint MUST exit nonzero on this file."""
import numpy as np


class Engine:
    def step(self, now):  # hornlint: hot-path
        sampled, accepted = self._step(self.params, self.cache)
        sampled = np.asarray(sampled)             # HL201: unannotated pull
        for slot in range(8):
            tok = int(accepted[slot])             # HL202: pull per iteration
            self.out[slot] = tok
        return sampled

    def commit(self, outs):  # hornlint: hot-path
        probs = self._step(self.params, outs)
        return probs.item()                       # HL201: .item() pull
