"""Compliant twin of ``violation_pool.py`` — hornlint MUST stay quiet.

Every allocation is either published before any raise, released on the
failure path, or returned straight to the caller.
"""


class Scheduler:
    def admit(self, req):
        if req.pages > self.budget:           # check before allocating
            raise ValueError("over budget")
        table = self.pool.alloc_pages(req.id, req.pages)
        self.tables[req.id] = table           # published

    def admit_guarded(self, req):
        table = self.pool.alloc_pages(req.id, req.pages)
        try:
            self._install(req, table)
        except Exception:
            self.pool.release(req.id)         # failure path releases
            raise
        self.tables[req.id] = table

    def prefork(self, req):
        return self.pool.fork(req.id)         # returned to caller
