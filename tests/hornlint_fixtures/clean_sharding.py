"""Compliant twin of violation_sharding.py — hornlint MUST stay silent."""
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.launch.mesh import shard_map

mesh = Mesh(jax.devices(), ("data", "model"))


def arity_ok(params, x, scale):
    def prog(p, a, s):
        return jnp.dot(a, p) * s

    fn = shard_map(prog, mesh=mesh,
                   in_specs=(P("model"), P(), P()),
                   out_specs=P())
    return fn(params, x, scale)


def known_axes():
    return P("data", "model")


def rank_ok():
    x = jnp.zeros((8, 16))

    def prog(a):
        return a * 2.0

    fn = shard_map(prog, mesh=mesh,
                   in_specs=(P("data", None),),
                   out_specs=P("data", None))
    return fn(x)


def bound_collective(x):
    def prog(a):
        return jax.lax.psum(a, "data")

    fn = shard_map(prog, mesh=mesh, in_specs=(P("data"),), out_specs=P())
    return fn(x)


def variable_axis(x, axis):
    # axis names from parameters are bound by the caller — not linted
    return jax.lax.psum(x, axis)


def local_mesh_axes():
    # a file-local mesh extends the axis vocabulary
    m = Mesh(jax.devices(), ("stage",))

    def prog(a):
        return jax.lax.pmean(a, "stage")

    fn = shard_map(prog, mesh=m, in_specs=(P("stage"),), out_specs=P())
    return fn(jnp.ones((4,)))
