"""Seeded HL6xx violations — hornlint MUST exit nonzero on this file."""
from functools import partial

import jax
import jax.experimental.pallas as pl
import jax.numpy as jnp


def _step(state, batch):
    return state + batch


def use_after_donate(state, batch):                   # HL601
    step = jax.jit(_step, donate_argnums=(0,))
    new_state = step(state, batch)
    return new_state + state                          # stale read


def double_donate(state, a, b):                       # HL602
    step = jax.jit(_step, donate_argnums=(0,))
    first = step(state, a)
    second = step(state, b)                           # state already donated
    return first + second


def loop_without_rebind(state, batches):              # HL602 across iters
    @partial(jax.jit, donate_argnums=(0,))
    def tick(s, b):
        return s + b

    total = 0.0
    for b in batches:
        total = total + tick(state, b)                # never rebinds state
    return total


def _alias_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def alias_out_of_range(x):                            # HL603
    return pl.pallas_call(
        _alias_kernel,
        grid=(4,),
        in_specs=[pl.BlockSpec((8,), lambda i: (i,))],
        out_specs=pl.BlockSpec((8,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((32,), jnp.float32),
        input_output_aliases={3: 0},                  # only 1 input
    )(x)


def alias_block_mismatch(x):                          # HL603
    return pl.pallas_call(
        _alias_kernel,
        grid=(4,),
        in_specs=[pl.BlockSpec((8,), lambda i: (i,))],
        out_specs=pl.BlockSpec((16,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((64,), jnp.float32),
        input_output_aliases={0: 0},                  # 8 vs 16 block
    )(x)
