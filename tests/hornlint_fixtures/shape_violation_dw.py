"""Seeded hornshape violation: double-write (HS003) — two grid steps
land on the same output block outside any declared accumulator carry.
``hornshape`` MUST exit nonzero."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

HORNSHAPE = {"entries": [
    {"fn": "doublewrite", "label": "double-write",
     "args": [{"array": [16]}]},
]}


def _copy(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def doublewrite(x):
    # i // 2 folds four grid steps onto two output blocks: each block is
    # written twice with no "arbitrary" carry declaration
    return pl.pallas_call(
        _copy, grid=(4,),
        in_specs=[pl.BlockSpec((4,), lambda i: (i,))],
        out_specs=pl.BlockSpec((8,), lambda i: (i // 2,)),
        out_shape=jax.ShapeDtypeStruct((16,), jnp.float32),
    )(x)
