"""Seeded HL3xx violations — hornlint MUST exit nonzero on this file."""
import functools

import jax
import jax.experimental.pallas as pl
import jax.numpy as jnp
from jax.experimental.pallas import tpu as pltpu


def _kernel(bt_ref, x_ref, o_ref, acc_ref, *, n_pages):
    b, p = pl.program_id(0), pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += x_ref[...]

    @pl.when(p == n_pages - 1)
    def _emit():
        o_ref[...] = acc_ref[...]


def carry_declared_parallel(x, bt):
    B, H, P = 4, 8, 2
    grid = (B, H, P)
    return pl.pallas_call(
        functools.partial(_kernel, n_pages=P),
        grid=grid,
        in_specs=[
            # HL304: unclamped block-table gather in the index_map
            pl.BlockSpec((1, 1), lambda b, h, p, *refs: (refs[0][b, p], 0)),
            pl.BlockSpec((1, 1), lambda b, h, p: (b, h)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda b, h: (b, h)),  # HL303: arity 2
        out_shape=jax.ShapeDtypeStruct((B, H), jnp.float32),
        scratch_shapes=[pltpu.VMEM((8, 8), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            # HL302: dim 2 carries the accumulator but is 'parallel'
            dimension_semantics=("parallel", "parallel", "parallel")),
    )(bt, x)


def semantics_rank_mismatch(x):
    grid = (4, 8, 2)
    return pl.pallas_call(
        functools.partial(_kernel, n_pages=2),
        grid=grid,
        in_specs=[pl.BlockSpec((1, 1), lambda b, h, p: (b, h)),
                  pl.BlockSpec((1, 1), lambda b, h, p: (b, h))],
        out_specs=pl.BlockSpec((1, 1), lambda b, h, p: (b, h)),
        out_shape=jax.ShapeDtypeStruct((4, 8), jnp.float32),
        scratch_shapes=[pltpu.VMEM((8, 8), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            # HL301: two entries for a rank-3 grid
            dimension_semantics=("parallel", "arbitrary")),
    )(x, x)
