"""Seeded HL1xx violations — hornlint MUST exit nonzero on this file.

Never imported or executed: the analyzer works on the AST alone, and the
filename avoids pytest's ``test_*`` collection pattern.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np

TABLE = jnp.zeros((8, 8))                     # HL101: jnp at import time


def step(params, tokens, n_fresh):
    if tokens.sum() > 0:                      # HL102: traced branch
        tokens = tokens * 2
    total = tokens @ params
    while total.max() > 1.0:                  # HL102: traced while
        total = total * 0.5
    return total


unified = jax.jit(step)


class Driver:
    def tick(self, toks):
        buf = np.zeros((len(toks), 4), np.int32)   # HL103: unbucketed
        out = self._step(buf, masks=[1, 2, 3])     # HL104: list static kwarg
        return out

    def rebuild(self, widths):
        fns = []
        for w in widths:
            fns.append(jax.jit(functools.partial(step, n_fresh=w)))  # HL105
        return fns
