"""Seeded hornshape violation: output coverage hole (HS002) — the grid
writes only half the output blocks.  ``hornshape`` MUST exit nonzero."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

HORNSHAPE = {"entries": [
    {"fn": "halfwritten", "label": "coverage-hole",
     "args": [{"array": [8]}]},
]}


def _copy(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def halfwritten(x):
    # grid extent 2 but the output has 4 blocks: blocks 2 and 3 are
    # never written and come back as uninitialized memory
    return pl.pallas_call(
        _copy, grid=(2,),
        in_specs=[pl.BlockSpec((4,), lambda i: (i,))],
        out_specs=pl.BlockSpec((4,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((16,), jnp.float32),
    )(x)
