"""Compliant twin of ``violation_sync.py`` — hornlint MUST stay quiet.

One deliberate annotated pull commits the tick; everything downstream of
it is host data and loops freely.
"""
import jax
import numpy as np


class Engine:
    def step(self, now):  # hornlint: hot-path
        sampled, accepted = self._step(self.params, self.cache)
        sampled, accepted = \
            jax.device_get((sampled, accepted))   # hornlint: sync-ok
        for slot in range(8):
            tok = int(accepted[slot])             # host array: free
            self.out[slot] = tok
        return sampled

    def commit(self, outs):  # hornlint: hot-path
        host = np.asarray(outs)                   # host input: not device
        return float(host.sum())
