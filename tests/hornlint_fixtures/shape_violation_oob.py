"""Seeded hornshape violations: OOB window (HS001) and a broken
null-page contract (HS005) — ``hornshape`` MUST exit nonzero here."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

HORNSHAPE = {"entries": [
    {"fn": "shifted", "label": "oob-shift",
     "args": [{"array": [16]}]},
    {"fn": "unclamped_gather", "label": "oob-gather",
     "args": [{"array": [2, 16]}, {"array": [8, 4]},
              {"table": "bt", "shape": [2, 4], "range": [0, 7]}],
     "null_page": ["bt", 0]},
]}


def _copy(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def shifted(x):
    # index map reads one block past the array on the last grid step
    return pl.pallas_call(
        _copy, grid=(4,),
        in_specs=[pl.BlockSpec((4,), lambda i: (i + 1,))],
        out_specs=pl.BlockSpec((4,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((16,), jnp.float32),
    )(x)


def _gather(bt_ref, x_ref, p_ref, o_ref):
    o_ref[...] = x_ref[...] + p_ref[...]


def unclamped_gather(x, pool, bt):
    # block-table gather with neither the dead-step null-page guard nor
    # the min-clamp to the table width: violates the NULL_PAGE contract
    return pl.pallas_call(
        _gather,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(2, 4),
            in_specs=[
                pl.BlockSpec((1, 4), lambda b, p, bt: (b, p)),
                pl.BlockSpec((1, 4), lambda b, p, bt: (bt[b, p], 0)),
            ],
            out_specs=pl.BlockSpec((1, 4), lambda b, p, bt: (b, p)),
        ),
        out_shape=jax.ShapeDtypeStruct((2, 16), jnp.float32),
    )(bt, x, pool)
