"""Compliant twin of ``violation_pallas.py`` — hornlint MUST stay quiet.

The paged-attention kernel's shape: full-rank dimension_semantics with
the carry dim 'arbitrary', index maps at grid arity (scalar-prefetch
``*refs`` tails allowed), block-table gathers clamped to the null page.
"""
import functools

import jax
import jax.experimental.pallas as pl
import jax.numpy as jnp
from jax.experimental.pallas import tpu as pltpu

DIM_SEMANTICS = ("parallel", "parallel", "arbitrary")


def _kernel(bt_ref, x_ref, o_ref, acc_ref, *, n_pages):
    b, p = pl.program_id(0), pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += x_ref[...]

    @pl.when(p == n_pages - 1)
    def _emit():
        o_ref[...] = acc_ref[...]


def page_of(b, p, refs, maxp):
    bt = refs[0]
    live = p < maxp
    return jnp.where(live, bt[b, jnp.minimum(p, maxp - 1)], 0)


def accumulating_scan(x, bt):
    B, H, P = 4, 8, 2
    grid = (B, H, P)
    return pl.pallas_call(
        functools.partial(_kernel, n_pages=P),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1),
                         lambda b, h, p, *refs: (page_of(b, p, refs, 2), 0)),
            pl.BlockSpec((1, 1), lambda b, h, p: (b, h)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda b, h, p: (b, h)),
        out_shape=jax.ShapeDtypeStruct((B, H), jnp.float32),
        scratch_shapes=[pltpu.VMEM((8, 8), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=DIM_SEMANTICS),
    )(bt, x)
