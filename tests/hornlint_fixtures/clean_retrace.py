"""Compliant twin of ``violation_retrace.py`` — hornlint MUST stay quiet.

Same shapes of code, each rewritten the way the serving stack does it:
constants stay numpy at import, branches test static structure only,
shapes are bucketed, static flags are hashable, jit cells are cached.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np

TABLE = np.zeros((8, 8))                      # host constant: fine


def pow2_bucket(n: int) -> int:
    return 1 << max(0, int(n - 1).bit_length())


def step(params, tokens, n_fresh, *, doubled=False):
    if doubled:                               # kw-only static flag: fine
        tokens = tokens * 2
    if tokens is None:                        # structure test: fine
        return params
    n = tokens.shape[0]
    if n > 4:                                 # shape-derived: fine
        tokens = tokens[:4]
    return tokens @ params


variants = {flag: jax.jit(functools.partial(step, doubled=flag))
            for flag in (False, True)}        # comprehension, not a loop


class Driver:
    def tick(self, toks):
        n = pow2_bucket(len(toks))            # bucketed width
        buf = np.zeros((n, 4), np.int32)
        out = self._step(buf, masks=(1, 2, 3))   # tuple kwarg: hashable
        return out

    def rebuild(self, widths):
        if 8 not in self._cells:              # cached compile cell
            self._cells[8] = jax.jit(functools.partial(step, n_fresh=8))
        return self._cells
