"""Seeded HL4xx violations — hornlint MUST exit nonzero on this file."""


class Scheduler:
    def admit(self, req):
        table = self.pool.alloc_pages(req.id, req.pages)
        if req.pages > self.budget:
            # HL401: pages leak on this raise path
            raise ValueError("over budget")
        self.tables[req.id] = table

    def prefork(self, req):
        # HL402: allocated, never published and never released
        child = self.pool.fork(req.id)
        self.stats["forks"] += 1
