"""Seeded HL5xx violations — hornlint MUST exit nonzero on this file."""
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.launch.mesh import shard_map

mesh = Mesh(jax.devices(), ("data", "model"))


def arity_mismatch(params, x):                        # HL501
    def prog(p, a, scale):
        return jnp.dot(a, p) * scale

    fn = shard_map(prog, mesh=mesh,
                   in_specs=(P("model"), P()),        # 2 specs, 3 params
                   out_specs=P())
    return fn(params, x)


def bogus_axis():                                     # HL502
    return P("data", "modle")                         # typo'd axis name


def rank_overflow():                                  # HL503
    x = jnp.zeros((8, 16))

    def prog(a):
        return a * 2.0

    fn = shard_map(prog, mesh=mesh,
                   in_specs=(P("data", "model", None),),   # 3 entries, rank 2
                   out_specs=P("data", "model", None))
    return fn(x)


def unbound_collective(x):                            # HL504: no shard_map
    return jax.lax.psum(x, "data")


def unknown_collective_axis(x):                       # HL504: bad axis name
    def prog(a):
        return jax.lax.psum(a, "stage9")

    fn = shard_map(prog, mesh=mesh, in_specs=(P("data"),), out_specs=P())
    return fn(x)
