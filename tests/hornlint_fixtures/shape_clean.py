"""Compliant twin of the shape_violation_* fixtures — ``hornshape`` MUST
prove every obligation here (exit 0): in-bounds windows, exact output
coverage, and a null-page-guarded, width-clamped block-table gather."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

HORNSHAPE = {"entries": [
    {"fn": "identity", "label": "exact-coverage",
     "args": [{"array": [16]}]},
    {"fn": "guarded_gather", "label": "clamped-gather",
     "args": [{"array": [2, 16]}, {"array": [8, 4]},
              {"table": "bt", "shape": [2, 4], "range": [0, 7]},
              {"table": "lengths", "shape": [2], "range": [0, 16]}],
     "null_page": ["bt", 0]},
]}


def _copy(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def identity(x):
    return pl.pallas_call(
        _copy, grid=(4,),
        in_specs=[pl.BlockSpec((4,), lambda i: (i,))],
        out_specs=pl.BlockSpec((4,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((16,), jnp.float32),
    )(x)


def _gather(bt_ref, len_ref, x_ref, p_ref, o_ref):
    o_ref[...] = x_ref[...] + p_ref[...]


def guarded_gather(x, pool, bt, lengths):
    # dead steps route to the null page, live steps clamp to the table
    # width — the same contract the paged-attention kernels carry
    def page_of(b, p, bt, lengths):
        live = p * 4 < lengths[b]
        return jnp.where(live, bt[b, jnp.minimum(p, 3)], 0)

    return pl.pallas_call(
        _gather,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(2, 4),
            in_specs=[
                pl.BlockSpec((1, 4), lambda b, p, bt, ln: (b, p)),
                pl.BlockSpec((1, 4),
                             lambda b, p, bt, ln: (page_of(b, p, bt, ln), 0)),
            ],
            out_specs=pl.BlockSpec((1, 4), lambda b, p, bt, ln: (b, p)),
        ),
        out_shape=jax.ShapeDtypeStruct((2, 16), jnp.float32),
    )(bt, lengths, x, pool)
