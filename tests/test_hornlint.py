"""hornlint + sanitizer tests: every rule family fires on its seeded
violation fixture and stays silent on the compliant twin, suppression
comments have exactly their documented scope, baselines round-trip, the
CLI exit codes hold, the repo itself lints clean against the committed
baseline, and the runtime Sanitizer catches a corrupted pool.
"""
import textwrap
from pathlib import Path

import pytest

from repro.analysis import hornlint, lint_paths, lint_source
from repro.analysis.core import (Finding, all_rules, diff_baseline,
                                 load_baseline, write_baseline)

FIXTURES = Path(__file__).parent / "hornlint_fixtures"
REPO = Path(__file__).resolve().parents[1]


def rules_of(findings):
    return {f.rule for f in findings}


def lint_fixture(name):
    return lint_paths([FIXTURES / name], root=REPO)


# ---------------------------------------------------------------------------
# retrace family (HL1xx)
# ---------------------------------------------------------------------------
def test_retrace_fixture_fires_every_rule():
    got = rules_of(lint_fixture("violation_retrace.py"))
    assert {"HL101", "HL102", "HL103", "HL104", "HL105"} <= got


def test_retrace_traced_branch_counts_both_loops():
    f = [x for x in lint_fixture("violation_retrace.py") if x.rule == "HL102"]
    assert len(f) == 2          # the if and the while
    assert all(x.qualname == "step" for x in f)


def test_retrace_clean_twin_is_quiet():
    assert lint_fixture("clean_retrace.py") == []


def test_retrace_shape_derived_branch_exempt():
    src = textwrap.dedent("""\
        import jax

        def step(params, tokens):
            if tokens.shape[0] > 4:
                tokens = tokens[:4]
            if tokens is None:
                return params
            return tokens @ params

        unified = jax.jit(step)
    """)
    assert lint_source(src) == []


def test_retrace_tainted_branch_inline():
    src = textwrap.dedent("""\
        import jax

        def step(params, tokens):
            if tokens.sum() > 0:
                params = params + 1
            return tokens @ params

        unified = jax.jit(step)
    """)
    assert rules_of(lint_source(src)) == {"HL102"}


# ---------------------------------------------------------------------------
# host-sync family (HL2xx)
# ---------------------------------------------------------------------------
def test_sync_fixture_fires():
    got = lint_fixture("violation_sync.py")
    assert rules_of(got) == {"HL201", "HL202"}
    assert sum(1 for f in got if f.rule == "HL201") == 2


def test_sync_clean_twin_is_quiet():
    assert lint_fixture("clean_sync.py") == []


def test_sync_requires_hot_scope_opt_in():
    # Same code as the violation fixture minus the hot-path marker: cold
    # functions pull freely, so nothing fires.
    src = (FIXTURES / "violation_sync.py").read_text()
    src = src.replace("# hornlint: hot-path", "")
    assert lint_source(src) == []


def test_sync_sink_result_launders_taint():
    src = textwrap.dedent("""\
        import numpy as np

        class Engine:
            def step(self):  # hornlint: hot-path
                out = self._step(self.params)
                host = np.asarray(out)       # the one (unannotated) pull
                for i in range(4):
                    tok = int(host[i])       # host data: no extra finding
                return tok
    """)
    got = lint_source(src)
    assert [f.rule for f in got] == ["HL201"]


# ---------------------------------------------------------------------------
# suppression semantics
# ---------------------------------------------------------------------------
def test_sync_ok_suppresses_sync_family_only():
    # sync-ok silences the HL2xx pull on its line...
    src = textwrap.dedent("""\
        import numpy as np

        class Engine:
            def step(self):  # hornlint: hot-path
                out = self._step(self.params)
                return np.asarray(out)   # hornlint: sync-ok
    """)
    assert lint_source(src) == []
    # ...but has no effect on other families on its line.
    src = textwrap.dedent("""\
        import jax.numpy as jnp
        T = jnp.zeros((8, 8))   # hornlint: sync-ok
    """)
    assert rules_of(lint_source(src)) == {"HL101"}


def test_ignore_comment_scopes():
    base = "import jax.numpy as jnp\nT = jnp.zeros((4,))"
    assert rules_of(lint_source(base)) == {"HL101"}
    assert lint_source(base + "   # hornlint: ignore") == []
    assert lint_source(base + "   # hornlint: ignore[HL101]") == []
    # listing a different rule does not suppress
    assert rules_of(lint_source(base + "   # hornlint: ignore[HL999]")) \
        == {"HL101"}


# ---------------------------------------------------------------------------
# pallas contracts (HL3xx)
# ---------------------------------------------------------------------------
def test_pallas_fixture_fires_every_rule():
    got = rules_of(lint_fixture("violation_pallas.py"))
    assert got == {"HL301", "HL302", "HL303", "HL304"}


def test_pallas_clean_twin_is_quiet():
    assert lint_fixture("clean_pallas.py") == []


def test_pallas_semantics_rank_checked_through_constants():
    got = lint_fixture("violation_pallas.py")
    mismatch = [f for f in got if f.rule == "HL301"]
    assert mismatch and "rank 3" in mismatch[0].message


def test_pallas_real_kernels_are_contract_clean():
    kernels = REPO / "src" / "repro" / "kernels"
    assert [f for f in lint_paths([kernels], root=REPO)
            if f.rule.startswith("HL3")] == []


# ---------------------------------------------------------------------------
# pool lifetime (HL4xx)
# ---------------------------------------------------------------------------
def test_pool_fixture_fires():
    got = rules_of(lint_fixture("violation_pool.py"))
    assert got == {"HL401", "HL402"}


def test_pool_clean_twin_is_quiet():
    assert lint_fixture("clean_pool.py") == []


def test_pool_try_finally_protects_raise():
    src = textwrap.dedent("""\
        class S:
            def admit(self, req):
                t = self.pool.alloc_pages(req.id, 4)
                try:
                    if req.bad:
                        raise ValueError("no")
                    self.tables[req.id] = t
                finally:
                    if req.id not in self.tables:
                        self.pool.release(req.id)
    """)
    assert lint_source(src) == []


def test_pool_unprotected_raise_leaks():
    src = textwrap.dedent("""\
        class S:
            def admit(self, req):
                t = self.pool.alloc_pages(req.id, 4)
                if req.bad:
                    raise ValueError("no")
                self.tables[req.id] = t
    """)
    assert rules_of(lint_source(src)) == {"HL401"}


# ---------------------------------------------------------------------------
# sharding contracts (HL5xx)
# ---------------------------------------------------------------------------
def test_sharding_fixture_fires_every_rule():
    got = rules_of(lint_fixture("violation_sharding.py"))
    assert got == {"HL501", "HL502", "HL503", "HL504"}


def test_sharding_clean_twin_is_quiet():
    assert lint_fixture("clean_sharding.py") == []


def test_sharding_arity_counts_the_right_nested_def():
    # two same-named nested fns: the spec count must check the one the
    # shard_map actually wraps, not the last one defined in the file
    f = [x for x in lint_fixture("violation_sharding.py")
         if x.rule == "HL501"]
    assert len(f) == 1 and "3 positional args" in f[0].message


def test_sharding_axis_vocabulary_includes_local_mesh():
    # clean_sharding.py's "stage" axis comes from its own Mesh(...) call
    f = [x for x in lint_fixture("violation_sharding.py")
         if x.rule == "HL502"]
    assert "'modle'" in f[0].message


def test_mesh_and_params_are_sharding_clean():
    paths = [REPO / "src" / "repro" / "launch" / "mesh.py",
             REPO / "src" / "repro" / "models" / "params.py"]
    got = [f for f in lint_paths(paths, root=REPO)
           if f.rule.startswith("HL5")]
    assert got == []


# ---------------------------------------------------------------------------
# donation / aliasing (HL6xx)
# ---------------------------------------------------------------------------
def test_donation_fixture_fires_every_rule():
    got = rules_of(lint_fixture("violation_donation.py"))
    assert got == {"HL601", "HL602", "HL603"}


def test_donation_clean_twin_is_quiet():
    assert lint_fixture("clean_donation.py") == []


def test_donation_rebind_loop_is_clean():
    src = textwrap.dedent("""\
        import jax

        def train(state, batches):
            step = jax.jit(lambda s, b: s + b, donate_argnums=(0,))
            for b in batches:
                state = step(state, b)
            return state
    """)
    assert lint_source(src) == []


def test_donation_cross_iteration_use_flags():
    src = textwrap.dedent("""\
        import jax

        def train(state, batches):
            step = jax.jit(lambda s, b: s + b, donate_argnums=(0,))
            outs = []
            for b in batches:
                outs.append(step(state, b))
            return outs
    """)
    assert rules_of(lint_source(src)) == {"HL602"}


def test_real_step_factories_are_donation_clean():
    steps = REPO / "src" / "repro" / "core" / "steps.py"
    got = [f for f in lint_paths([steps], root=REPO)
           if f.rule.startswith("HL6")]
    assert got == []


# ---------------------------------------------------------------------------
# baseline round-trip + CLI exit codes
# ---------------------------------------------------------------------------
def test_baseline_round_trip(tmp_path):
    findings = lint_fixture("violation_retrace.py")
    assert findings
    base = tmp_path / "baseline.json"
    write_baseline(findings, base)
    loaded = load_baseline(base)
    assert set(loaded) == {f.fingerprint for f in findings}
    new, fixed = diff_baseline(findings, loaded)
    assert new == [] and fixed == []
    # CLI agrees: baselined findings don't fail the run
    rc = hornlint.main([str(FIXTURES / "violation_retrace.py"),
                        "--baseline", str(base), "--root", str(REPO)])
    assert rc == 0


def test_baseline_reports_fixed_entries():
    stale = Finding("HL999", "gone.py", 1, 0, "was fixed long ago")
    new, fixed = diff_baseline([], {stale.fingerprint: {
        "fingerprint": stale.fingerprint, "rule": stale.rule,
        "path": stale.path, "qualname": "", "message": stale.message}})
    assert new == [] and len(fixed) == 1


def test_fingerprint_survives_line_drift():
    a = Finding("HL201", "e.py", 10, 4, "msg", "Engine.step")
    b = Finding("HL201", "e.py", 99, 4, "msg", "Engine.step")
    assert a.fingerprint == b.fingerprint
    c = Finding("HL201", "e.py", 10, 4, "other msg", "Engine.step")
    assert a.fingerprint != c.fingerprint


@pytest.mark.parametrize("name", ["violation_retrace.py", "violation_sync.py",
                                  "violation_pallas.py", "violation_pool.py",
                                  "violation_sharding.py",
                                  "violation_donation.py"])
def test_cli_nonzero_on_violation_fixture(name):
    assert hornlint.main([str(FIXTURES / name), "--baseline", "none"]) == 1


@pytest.mark.parametrize("name", ["clean_retrace.py", "clean_sync.py",
                                  "clean_pallas.py", "clean_pool.py",
                                  "clean_sharding.py", "clean_donation.py"])
def test_cli_zero_on_clean_fixture(name):
    assert hornlint.main([str(FIXTURES / name), "--baseline", "none"]) == 0


def test_cli_github_annotations(capsys):
    rc = hornlint.main([str(FIXTURES / "violation_sharding.py"),
                        "--baseline", "none", "--github"])
    assert rc == 1
    out = capsys.readouterr().out
    ann = [ln for ln in out.splitlines() if ln.startswith("::error ")]
    assert ann and all("file=" in ln and "line=" in ln
                       and ",title=hornlint HL5" in ln for ln in ann)


def test_cli_bad_invocation():
    assert hornlint.main(["--rules", "HL999"]) == 2
    assert hornlint.main(["no/such/path.py"]) == 2


def test_rule_catalogue_is_complete():
    got = set(all_rules())
    assert {"HL101", "HL102", "HL103", "HL104", "HL105",
            "HL201", "HL202",
            "HL301", "HL302", "HL303", "HL304",
            "HL401", "HL402",
            "HL501", "HL502", "HL503", "HL504",
            "HL601", "HL602", "HL603"} <= got


# ---------------------------------------------------------------------------
# the repo gates itself
# ---------------------------------------------------------------------------
def test_repo_lints_clean_against_committed_baseline():
    rc = hornlint.main([str(REPO / "src"), str(REPO / "benchmarks"),
                        "--baseline", str(hornlint.DEFAULT_BASELINE),
                        "--root", str(REPO)])
    assert rc == 0


# ---------------------------------------------------------------------------
# runtime sanitizer
# ---------------------------------------------------------------------------
class _StubSched:
    def __init__(self):
        self.running = {}


class _StubEngine:
    def __init__(self, pool):
        self.pool = pool
        self.spec = None
        self._bt = None
        self.sched = _StubSched()
        self.steps = 0

    def step(self, now):
        self.steps += 1
        return []


def test_sanitizer_quiet_on_healthy_pool():
    from repro.analysis.sanitize import Sanitizer
    from repro.serving.kv_cache import PagePool

    pool = PagePool(num_pages=9, page_size=4)
    pool.alloc(1, 10)
    eng = _StubEngine(pool)
    san = Sanitizer().attach(eng)
    for t in range(3):
        eng.step(float(t))
    assert san.ticks_checked == 3
    assert san.alerts == []
    assert "0 invariant alerts" in san.render_report()


def test_sanitizer_catches_leaked_pages():
    from repro.analysis.sanitize import Sanitizer
    from repro.serving.kv_cache import PagePool

    pool = PagePool(num_pages=9, page_size=4)
    pool.alloc(1, 10)
    # Lose the table without returning its pages: a textbook leak —
    # used_pages still counts them, no live table references them.
    pool._tables.pop(1)
    san = Sanitizer()
    san.check(_StubEngine(pool), tick=7)
    assert any(a.kind == "pool-leak" for a in san.alerts)
    assert san.report()["alerts"] >= 1
    assert "tick 7" in san.render_report()


def test_sanitizer_check_every_throttles():
    from repro.analysis.sanitize import Sanitizer
    from repro.serving.kv_cache import PagePool

    eng = _StubEngine(PagePool(num_pages=5, page_size=4))
    san = Sanitizer(check_every=2).attach(eng)
    for t in range(4):
        eng.step(float(t))
    assert san.ticks_checked == 2
