"""Prefix cache + ref-counted copy-on-write PagePool tests.

Pool-level: refcount/fork/adopt/COW semantics, content-hash chaining,
publish/match/LRU-evict flow, descriptive double-free errors, and a
hypothesis property test driving random alloc/ensure/fork/free/evict
interleavings against ``check_invariants`` (refcounts match tables, cached
pages are unreferenced by live sequences, no COW write ever lands on a
page with refcount > 1).

Engine-level acceptance: a prompt served twice is byte-identical with the
second prefill mostly skipped; an ``ensemble=...`` request with the prefix
cache on emits byte-identical streams to the per-member re-prefill path
(greedy and temp > 0) while prefilling ~1/G of the tokens; a shared-
system-prompt mix hits >= 50%; decode runs one tick per token (the
redundant re-feed chunk regression).
"""
import numpy as np
import jax
import pytest

from repro.configs.base import HornConfig, get_model_config, reduced
from repro.models import api
from repro.serving import (Engine, EngineConfig, ModelBank, PagePool,
                           PagePoolOOM, Router, chain_hashes)

P = 4  # pool-test page size


# ---------------------------------------------------------------------------
# content hashing
# ---------------------------------------------------------------------------
def test_chain_hashes_pin_the_whole_prefix():
    a = chain_hashes(b"dense", np.arange(12), P)
    b = chain_hashes(b"dense", np.arange(12), P)
    assert a == b and len(a) == 3                # deterministic, full pages
    # a change in block 0 changes EVERY downstream hash (the chain)
    toks = np.arange(12)
    toks[0] += 1
    c = chain_hashes(b"dense", toks, P)
    assert all(x != y for x, y in zip(a, c))
    # same tokens under another namespace never collide
    d = chain_hashes(b"sub:1", np.arange(12), P)
    assert all(x != y for x, y in zip(a, d))
    # partial trailing block contributes no hash
    assert chain_hashes(b"dense", np.arange(11), P) == a[:2]


# ---------------------------------------------------------------------------
# pool lifecycle bugfixes
# ---------------------------------------------------------------------------
def test_double_free_raises_descriptive_error():
    pool = PagePool(num_pages=8, page_size=P)
    pool.alloc(7, 6)
    assert pool.free_seq(7) == 2
    with pytest.raises(ValueError, match="double free"):
        pool.free_seq(7)                         # not a bare KeyError
    with pytest.raises(ValueError, match="not allocated"):
        pool.free_seq(99)
    with pytest.raises(ValueError, match="not allocated"):
        pool.table(99)
    with pytest.raises(ValueError, match="not allocated"):
        pool.ensure(99, 4)
    pool.check_invariants()


def test_engine_rejects_empty_prompt(tiny):
    cfg, params = tiny
    eng = _engine(cfg, params, prefix_cache=True)
    with pytest.raises(ValueError, match="[Ee]mpty prompt|length 0"):
        eng.submit(np.zeros((0,), np.int32), 4)
    with pytest.raises(ValueError):
        eng.submit([], 4)
    assert not eng.sched.has_work()              # nothing was queued


# ---------------------------------------------------------------------------
# refcounts, fork, COW, publish/match/evict
# ---------------------------------------------------------------------------
def test_fork_shares_and_cow_isolates():
    pool = PagePool(num_pages=12, page_size=P, prefix_cache=True)
    t0 = list(pool.alloc(0, 8))
    pool.fork(0, 1)
    assert pool.table(1) == t0
    assert all(pool.refcount(p) == 2 for p in t0)
    pool.check_invariants()
    # writer 1 touches page 1 -> private copy; page 0 stays shared
    pairs = pool.prepare_write(1, P, 2 * P)
    assert len(pairs) == 1 and pairs[0][0] == t0[1]
    assert pool.table(0) == t0                   # victim table untouched
    assert pool.table(1)[0] == t0[0] and pool.table(1)[1] != t0[1]
    assert pool.refcount(t0[0]) == 2 and pool.refcount(t0[1]) == 1
    # the last holder writes in place: no copy
    assert pool.prepare_write(0, P, 2 * P) == []
    pool.check_invariants()
    pool.free_seq(0)
    pool.free_seq(1)
    assert pool.used_pages == 0
    pool.check_invariants()


def test_publish_match_lru_evict_roundtrip():
    pool = PagePool(num_pages=8, page_size=P, prefix_cache=True)
    toks = np.arange(3 * P, dtype=np.int32)
    hs = chain_hashes(b"dense", toks, P)
    t = list(pool.alloc(0, 3 * P))
    assert pool.publish_prefix(0, hs, 3) == 3
    # indexed while live: a concurrent request adopts at refcount 2
    hit = pool.match_pages(hs)
    assert hit == t
    pool.alloc_pages(1, 0, cached=hit)
    assert all(pool.refcount(p) == 2 for p in t)
    pool.check_invariants()
    pool.free_seq(0)
    pool.free_seq(1)
    # refcount 0 + published -> held by the cache, not freed
    assert pool.used_pages == 0 and pool.cached_pages == 3
    assert pool.match_pages(hs) == t             # still matchable
    # allocation pressure evicts LRU-first — deepest blocks retired first,
    # so the surviving entry is the shallow prefix page, still matchable
    # through the chain walk
    pool.alloc_pages(2, pool.free_pages + 2)
    assert pool.cached_pages == 1
    assert pool.match_pages(hs) == [t[0]]
    pool.check_invariants()


def test_match_is_capped_and_chained():
    pool = PagePool(num_pages=10, page_size=P, prefix_cache=True)
    toks = np.arange(3 * P, dtype=np.int32)
    hs = chain_hashes(b"dense", toks, P)
    pool.alloc(0, 3 * P)
    pool.publish_prefix(0, hs, 3)
    pages, n = pool.match_prefix(b"dense", toks)
    assert n == 3 * P and len(pages) == 3
    # a fresh prompt must keep its last token: cap excludes the final page
    pages, n = pool.match_prefix(b"dense", toks, max_tokens=3 * P - 1)
    assert n == 2 * P
    # divergence after page 0 matches exactly one page
    toks2 = toks.copy()
    toks2[P] += 1
    pages, n = pool.match_prefix(b"dense", toks2)
    assert n == P
    assert pool.match_prefix(b"sub:0", toks) == ([], 0)
    pool.free_seq(0)
    pool.check_invariants()


def test_deferred_promise_blocks_interlopers():
    pool = PagePool(num_pages=8, page_size=P)   # 7 allocatable
    pool.alloc_pages(0, 2, deferred=3)          # owns 2, promises 3 more
    assert pool.deferred_pages == 3
    with pytest.raises(PagePoolOOM):
        pool.alloc_pages(1, 3)                  # only 7-2-3=2 unpromised
    pool.alloc_pages(1, 2)
    pool.ensure(0, 5 * P)                       # redeems the promise
    assert pool.deferred_pages == 0
    pool.check_invariants()
    pool.free_seq(0)
    pool.free_seq(1)
    pool.check_invariants()


# ---------------------------------------------------------------------------
# lookup-lifecycle bugfixes: peek probes, negative cache, null hit rate
# ---------------------------------------------------------------------------
def test_peek_match_counts_nothing():
    pool = PagePool(num_pages=8, page_size=P, prefix_cache=True)
    toks = np.arange(2 * P, dtype=np.int32)
    hs = chain_hashes(b"dense", toks, P)
    t = list(pool.alloc(0, 2 * P))
    pool.publish_prefix(0, hs, 2)
    for _ in range(4):
        assert pool.match_pages(hs, peek=True) == t
    assert pool.cache.hits == 0 and pool.cache.misses == 0
    assert pool.match_pages(hs) == t             # committed lookup counts
    assert pool.cache.hits == 2 and pool.cache.misses == 0
    pool.free_seq(0)
    pool.check_invariants()


def test_blocked_head_replans_without_stat_or_lru_distortion():
    """The regression: a blocked FCFS head replans (and so re-probes the
    prefix cache) every tick; those feasibility peeks must not inflate the
    hit/miss counters or touch LRU recency — only the tick that actually
    adopts the pages commits one lookup."""
    from repro.serving import FCFSScheduler, Request

    pool = PagePool(num_pages=10, page_size=P, prefix_cache=True)
    toks = np.arange(3 * P, dtype=np.int32)
    hs = chain_hashes(b"dense", toks, P)
    pool.alloc(100, 3 * P)
    pool.publish_prefix(100, hs, 3)
    pool.free_seq(100)                           # 3 cached, evictable pages
    lru_before = list(pool.cache.lru)
    pool.alloc_pages(101, pool.free_pages)       # a hog drains the free list
    sched = FCFSScheduler(2, pool, policy="on_demand")
    prompt = np.concatenate([toks, np.asarray([7, 8, 9], np.int32)])
    sched.submit(Request(id=0, prompt=prompt, max_new_tokens=4))
    for _ in range(5):                           # blocked head, 5 replans
        assert sched.admit(0.0) == []
    assert pool.cache.hits == 0 and pool.cache.misses == 0, \
        "feasibility peeks counted as cache traffic"
    assert list(pool.cache.lru) == lru_before, \
        "a blocked head refreshed LRU recency"
    pool.free_seq(101)
    admitted = sched.admit(1.0)                  # now it fits: adopt + count
    assert len(admitted) == 1 and admitted[0].num_cached_tokens == 3 * P
    assert pool.cache.hits == 3 and pool.cache.misses == 0
    pool.check_invariants()


def test_negative_cache_remembers_cold_chain_heads():
    pool = PagePool(num_pages=8, page_size=P, prefix_cache=True)
    toks = np.arange(2 * P, dtype=np.int32)
    hs = chain_hashes(b"dense", toks, P)
    assert pool.match_pages(hs, peek=True) == []
    assert hs[0] in pool.cache.neg               # cold head remembered
    base = pool.cache.neg_hits
    pool.match_pages(hs, peek=True)
    pool.match_pages(hs)
    assert pool.cache.neg_hits == base + 2       # walks short-circuited
    # publish invalidates the negative set: the same lookup now hits
    t = list(pool.alloc(0, 2 * P))
    pool.publish_prefix(0, hs, 2)
    assert not pool.cache.neg
    assert pool.match_pages(hs) == t
    pool.check_invariants()
    # a partial hit (miss past page 0) is NOT a cold head: no neg entry
    toks2 = toks.copy()
    toks2[P] += 1
    hs2 = chain_hashes(b"dense", toks2, P)
    assert pool.match_pages(hs2, peek=True) == [t[0]]
    assert hs2[0] not in pool.cache.neg
    pool.free_seq(0)


def test_prefix_hit_rate_is_none_when_nothing_eligible():
    cfg = reduced(get_model_config("qwen3-1.7b"), dtype="float32")
    params = api.model_init(jax.random.key(0), cfg)
    for prefix_cache in (False, True):
        eng = Engine(cfg, params,
                     EngineConfig(num_slots=2, num_pages=16, page_size=8,
                                  max_prompt_len=16, max_new_tokens=2,
                                  kv_dtype="float32",
                                  compute_dtype="float32",
                                  prefix_cache=prefix_cache))
        assert eng.prefix_hit_rate is None       # no lookup was eligible
    eng.submit(np.arange(1, 10, dtype=np.int32), 2)
    eng.run()
    assert eng.prefix_hit_rate == 0.0            # eligible but cold


# ---------------------------------------------------------------------------
# engine-level acceptance
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def tiny():
    cfg = reduced(get_model_config("qwen3-1.7b"), dtype="float32")
    return cfg, api.model_init(jax.random.key(0), cfg)


def _engine(cfg, params, *, prefix_cache, bank=None, slots=3,
            temperature=0.0, pages=64, budget=32):
    return Engine(cfg, params,
                  EngineConfig(num_slots=slots, num_pages=pages, page_size=8,
                               max_prompt_len=32, max_new_tokens=5,
                               token_budget=budget, temperature=temperature,
                               policy="on_demand", kv_dtype="float32",
                               compute_dtype="float32",
                               prefix_cache=prefix_cache),
                  bank=bank,
                  router=Router(bank.num_submodels) if bank else None)


def test_solo_prefix_hit_is_byte_identical(tiny):
    cfg, params = tiny
    rng = np.random.default_rng(3)
    prompt = rng.integers(1, cfg.vocab_size, (20,)).astype(np.int32)
    cold = _engine(cfg, params, prefix_cache=False)
    cold.submit(prompt, 5)
    cold.run()
    want = list(cold.sched.finished[0].out_tokens)

    warm = _engine(cfg, params, prefix_cache=True)
    r1 = warm.submit(prompt, 5)
    warm.run()
    r2 = warm.submit(prompt, 5)
    warm.run()
    assert list(r1.out_tokens) == list(r2.out_tokens) == want
    # 20-token prompt, 8-token pages, last token never cached: 2 full pages
    assert r2.num_cached_tokens == 16
    assert warm.cache_hit_tokens == 16 and warm.prefill_tok_saved >= 16
    warm.pool.check_invariants()
    assert warm.pool.used_pages == 0             # retired into the cache
    assert warm.pool.cached_pages > 0


def test_live_pages_shared_across_concurrent_requests(tiny):
    """The millions-of-users path: request 2 adopts request 1's pages
    while request 1 is still decoding against them (refcount 2)."""
    cfg, params = tiny
    rng = np.random.default_rng(4)
    prompt = rng.integers(1, cfg.vocab_size, (17,)).astype(np.int32)
    eng = _engine(cfg, params, prefix_cache=True, slots=2)
    r1 = eng.submit(prompt, 5)
    while not r1.out_tokens:                     # prefill + publish
        eng.step()
    r2 = eng.submit(prompt, 5)
    eng.run()
    assert r2.num_cached_tokens == 16
    assert list(r1.out_tokens) == list(r2.out_tokens)
    eng.pool.check_invariants()


@pytest.mark.parametrize("temperature", [0.0, 0.8])
@pytest.mark.parametrize("combine", ["mean_logit", "majority_vote"])
def test_ensemble_share_parity_and_prefill_savings(tiny, temperature,
                                                   combine):
    """The acceptance bar: with the prefix cache on, an ensemble request
    emits byte-identical combined streams to the per-member re-prefill
    path (greedy and sampled) while prefilling ~1/G of the tokens — the
    leader encodes the shared context once, members fork its pages and
    only their tails copy-on-write."""
    cfg, params = tiny
    G = 3
    bank = ModelBank(cfg, HornConfig(enabled=True, keep_hidden=0.5,
                                     keep_input=1.0, block_size=4), G,
                     seed=1)
    rng = np.random.default_rng(5)
    prompt = rng.integers(1, cfg.vocab_size, (19,)).astype(np.int32)
    L = len(prompt)

    cold = _engine(cfg, params, prefix_cache=False, bank=bank,
                   temperature=temperature)
    gc = cold.submit(prompt, 5, ensemble=combine)
    cold.run()
    warm = _engine(cfg, params, prefix_cache=True, bank=bank,
                   temperature=temperature)
    gw = warm.submit(prompt, 5, ensemble=combine)
    warm.run()

    assert gw.out_tokens == gc.out_tokens
    for m in gw.members:
        assert list(m.out_tokens) == gw.out_tokens
    # per-member re-prefill costs G * L; the share path costs the shared
    # context once plus one masked token per member
    assert cold.prefill_tokens == G * L
    assert warm.prefill_tokens == (L - 1) + G
    assert warm.prefill_tok_saved == (G - 1) * (L - 1)
    # tails diverged off the shared partial page: one COW copy per member
    # beyond the last holder
    assert warm.cow_page_copies == G - 1
    for eng in (cold, warm):
        eng.pool.check_invariants()
        assert eng.pool.used_pages == 0


def test_reserve_ensemble_fits_exactly_sized_pool(tiny):
    """Deferred-reserve accounting regression: an ensemble whose
    worst-case (leader 3 pages + 2 member-tail promises) exactly equals
    pool capacity must serve without preemption.  Members COW the shared
    boundary page BEFORE the leader, redeeming their own credits; the
    leader — whose reserve covers the original page — keeps it in place.
    (Leader-first write-prep used to draw an unreserved page and OOM.)"""
    cfg, params = tiny
    G = 3
    bank = ModelBank(cfg, HornConfig(enabled=True, keep_hidden=0.5,
                                     keep_input=1.0, block_size=4), G,
                     seed=1)
    rng = np.random.default_rng(9)
    prompt = rng.integers(1, cfg.vocab_size, (19,)).astype(np.int32)
    eng = Engine(cfg, params,
                 EngineConfig(num_slots=G, num_pages=6, page_size=8,
                              max_prompt_len=24, max_new_tokens=5,
                              token_budget=24, policy="reserve",
                              kv_dtype="float32", compute_dtype="float32",
                              prefix_cache=True),
                 bank=bank, router=Router(G))
    group = eng.submit(prompt, 5, ensemble="mean_logit")
    eng.run()
    assert group.finished and len(group.out_tokens) == 5
    assert eng.preemptions == 0, "reserve must never preempt"
    assert eng.cow_page_copies == G - 1
    eng.pool.check_invariants()
    assert eng.pool.deferred_pages == 0


def test_shared_system_prompt_mix_hit_rate(tiny):
    """>= 50% of cache-eligible prompt tokens served from the cache when
    requests share a system prefix (3 pages of 8) with unique tails."""
    cfg, params = tiny
    rng = np.random.default_rng(6)
    sys_prompt = rng.integers(1, cfg.vocab_size, (24,)).astype(np.int32)
    eng = _engine(cfg, params, prefix_cache=True, slots=2, pages=128)
    outs = []
    for _ in range(6):
        tail = rng.integers(1, cfg.vocab_size, (8,)).astype(np.int32)
        outs.append(eng.submit(np.concatenate([sys_prompt, tail]), 4))
        eng.run()
    # requests 2..6 each match the 24-token system prefix of 31 eligible
    assert eng.cache_hit_tokens == 5 * 24
    assert eng.prefix_hit_rate >= 0.5
    eng.pool.check_invariants()


def test_decode_is_one_tick_per_token(tiny):
    """Regression: decode used to alternate with a redundant 1-token
    re-feed chunk (prefill_pos lagging the decode write), doubling ticks
    per generated token."""
    cfg, params = tiny
    prompt = np.arange(1, 9, dtype=np.int32)
    eng = _engine(cfg, params, prefix_cache=True)
    eng.submit(prompt, 5)
    eng.run()
    # 1 prefill tick (records token 1) + 4 decode ticks, + admission slack
    assert eng.steps <= 6, f"{eng.steps} ticks for 5 tokens"
    assert eng.prefill_tokens == 8               # the prompt, once
