"""hornshape tests: the symbolic domain is sound on hand-checked facts,
every committed kernel geometry proves its BlockSpec/grid obligations
(symbolically, with brute-force agreement), the seeded shape fixtures are
rejected with concrete counterexample grid points, the compliant twin
proves clean, and the runtime cross-check is quiet at a sane serving
geometry.
"""
from pathlib import Path

import pytest

from repro.analysis import hornshape
from repro.analysis.blockspec_verify import (Geometry, Operand, brute_force,
                                             verify)
from repro.analysis.symbolic import (bounds, congruence, concrete_all,
                                     free_vars, prove, s_max, s_min, seq,
                                     sym, var)

FIXTURES = Path(__file__).parent / "hornlint_fixtures"
REPO = Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# symbolic domain
# ---------------------------------------------------------------------------
def test_bounds_affine_cancellation():
    g = var("g")
    env = {"g": (0, 7)}
    # 3g - g + 2 = 2g + 2: exact interval, not the naive [2-7, 23]
    assert bounds(3 * g - g + 2, env) == (2, 16)
    assert bounds(g - g, env) == (0, 0)


def test_bounds_floordiv_min_max():
    g = var("g")
    env = {"g": (0, 9)}
    assert bounds(g // 2, env) == (0, 4)
    assert bounds(s_min(g, 5), env) == (0, 5)
    assert bounds(s_max(g, 5), env) == (5, 9)


def test_congruence_tracks_strides():
    g = var("g")
    env = {"g": (0, 7)}
    m, r = congruence(4 * g + 2, env)
    assert m == 4 and r == 2
    # (4g + 2) % 2 == 0 exactly
    assert congruence((4 * g + 2) % 2, env) == (0, 0)


def test_prove_three_valued():
    g = var("g")
    env = {"g": (0, 3)}
    assert prove(g <= 3, env) is True
    assert prove(g > 3, env) is False
    assert prove(g > 1, env) is None          # depends on g
    # congruence refutation: 4g + 2 is never divisible by 4
    assert prove((4 * g + 2) % 4 == 0, env) is False


def test_concrete_enumeration_is_exact():
    g = var("g")
    vals = concrete_all((g + 1) // 2, {"g": 5})
    assert vals == frozenset({3})


def test_structural_equality_helper():
    g = var("g")
    assert seq(g + 1, g + 1)
    assert not seq(g + 1, g + 2)
    assert free_vars(g + var("h") * 2) == {"g", "h"}


# ---------------------------------------------------------------------------
# the committed kernels prove
# ---------------------------------------------------------------------------
def test_all_kernels_prove():
    results = hornshape.check_kernels(REPO)
    assert len(results) >= 8          # every registry entry produced a report
    for rel, rep in results:
        assert rep.ok, f"{rel} {rep.geometry.name}: {rep.findings}"
        assert rep.proved_symbolically() > 0, \
            f"{rel} {rep.geometry.name} fell back to enumeration everywhere"


def test_kernel_verdicts_match_brute_force():
    # ground truth: concrete enumeration over every grid point agrees with
    # the symbolic verdict on every shared obligation
    for rel, rep in hornshape.check_kernels(REPO):
        bf = brute_force(rep.geometry)
        for key, truth in bf.items():
            got = rep.verdicts.get(key)
            if got is not None:
                assert got == truth, \
                    f"{rel} {rep.geometry.name} {key}: " \
                    f"symbolic={got!r} brute-force={truth!r}"


def test_null_page_constant_is_hoisted():
    from repro.kernels.paged_attention.kernel import NULL_PAGE
    assert NULL_PAGE == 0
    # the registry run checks the clamp contract against it
    results = hornshape.check_kernels(REPO)
    paged = [rep for rel, rep in results if "paged_attention" in rel]
    assert any(("null_page",) in rep.verdicts for rep in paged)


# ---------------------------------------------------------------------------
# seeded fixtures
# ---------------------------------------------------------------------------
def _fixture_findings(name):
    reports = hornshape.check_file(FIXTURES / name)
    return [f for rep in reports for f in rep.findings]


def test_oob_fixture_rejected_with_counterexample():
    findings = _fixture_findings("shape_violation_oob.py")
    rules = {f.rule for f in findings}
    assert "HS001" in rules and "HS005" in rules
    oob = next(f for f in findings if f.rule == "HS001")
    assert "counterexample grid point" in oob.message
    assert "(g0=3)" in oob.message


def test_hole_fixture_rejected():
    findings = _fixture_findings("shape_violation_hole.py")
    assert {f.rule for f in findings} == {"HS002"}
    assert "never written" in findings[0].message


def test_double_write_fixture_rejected():
    findings = _fixture_findings("shape_violation_dw.py")
    assert {f.rule for f in findings} == {"HS003"}
    assert "written by both" in findings[0].message


def test_clean_fixture_proves():
    reports = hornshape.check_file(FIXTURES / "shape_clean.py")
    assert all(rep.ok for rep in reports)
    assert all(rep.proved_symbolically() == len(rep.verdicts)
               for rep in reports)


def test_cli_exit_codes(capsys):
    assert hornshape.main([str(FIXTURES / "shape_violation_oob.py")]) == 1
    assert hornshape.main([str(FIXTURES / "shape_clean.py")]) == 0
    capsys.readouterr()


def test_cli_json_shape(capsys):
    rc = hornshape.main([str(FIXTURES / "shape_violation_hole.py"),
                         "--json"])
    assert rc == 1
    import json
    doc = json.loads(capsys.readouterr().out)
    assert doc["ok"] is False
    (res,) = doc["results"]
    assert res["grid"] == [2]
    assert res["findings"][0]["rule"] == "HS002"


# ---------------------------------------------------------------------------
# direct Geometry API (no interpreter in the loop)
# ---------------------------------------------------------------------------
def _geom(grid, out_map, *, nblocks=4, bs=4, semantics=None):
    return Geometry(
        name="unit", grid=grid,
        in_operands=[Operand("in0", (nblocks * bs,), "float32", (bs,),
                             lambda *g: (g[0],), None)],
        out_operands=[Operand("out0", (nblocks * bs,), "float32", (bs,),
                              out_map, None)],
        dimension_semantics=semantics)


def test_accumulator_carry_is_not_a_double_write():
    # out map ignores the (arbitrary) reduction dim: legal carry pattern
    g = Geometry(
        name="carry", grid=(4, 3),
        in_operands=[Operand("in0", (16, 6), "float32", (4, 2),
                             lambda i, k: (i, k), None)],
        out_operands=[Operand("out0", (16,), "float32", (4,),
                              lambda i, k: (i,), None)],
        dimension_semantics=("parallel", "arbitrary"))
    rep = verify(g)
    assert rep.ok
    # the same revisit declared "parallel" is flagged
    g2 = Geometry(
        name="carry-bad", grid=(4, 3),
        in_operands=g.in_operands, out_operands=g.out_operands,
        dimension_semantics=("parallel", "parallel"))
    rep2 = verify(g2)
    assert {f.rule for f in rep2.findings} == {"HS003"}


def test_permuted_output_map_proves():
    g = Geometry(
        name="permute", grid=(2, 3),
        in_operands=[Operand("in0", (2, 3), "float32", (1, 1),
                             lambda b, c: (b, c), None)],
        out_operands=[Operand("out0", (3, 2), "float32", (1, 1),
                              lambda b, c: (c, b), None)])
    rep = verify(g)
    assert rep.ok


def test_alias_shape_mismatch_is_hs004():
    g = Geometry(
        name="alias", grid=(4,),
        in_operands=[Operand("in0", (16,), "float32", (4,),
                             lambda i: (i,), None)],
        out_operands=[Operand("out0", (16,), "bfloat16", (4,),
                              lambda i: (i,), None)],
        input_output_aliases={0: 0})
    rep = verify(g)
    assert any(f.rule == "HS004" for f in rep.findings)


# ---------------------------------------------------------------------------
# runtime twin
# ---------------------------------------------------------------------------
def test_crosscheck_quiet_at_serving_geometry():
    alerts = hornshape.crosscheck_paged_geometry(
        batch=4, kv_heads=2, head_dim=16, page_size=4, num_pages=32,
        max_pages=8, pages_per_step=2)
    assert alerts == []


def test_crosscheck_quiet_quantized():
    alerts = hornshape.crosscheck_paged_geometry(
        batch=2, kv_heads=2, head_dim=8, page_size=4, num_pages=16,
        max_pages=4, quantized=True)
    assert alerts == []
