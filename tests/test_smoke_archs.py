"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step + one prefill/decode step on CPU; asserts shapes + no NaNs.
The FULL configs are exercised only via the dry-run (no allocation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (HornConfig, RunConfig, ShapeConfig,
                                get_model_config, list_archs, reduced)
from repro.core import steps
from repro.launch.mesh import make_test_mesh
from repro.models import api
from repro.models import transformer as T

ARCHS = [a for a in list_archs() if a != "horn-mnist"]


def make_run(arch, kind="train", seq=64, batch=4):
    cfg = reduced(get_model_config(arch))
    shape = ShapeConfig("smoke", kind, seq, batch)
    return RunConfig(model=cfg, shape=shape,
                     horn=HornConfig(enabled=True, num_groups=2),
                     learning_rate=0.01, momentum=0.9)


def make_batch(run, rng=None):
    cfg, shape = run.model, run.shape
    B, S = shape.global_batch, shape.seq_len
    text = S - (cfg.num_patches or 0)
    batch = {"tokens": jnp.ones((B, text), jnp.int32),
             "labels": jnp.ones((B, text), jnp.int32)}
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.ones((B, cfg.encoder_seq, cfg.d_model),
                                   jnp.bfloat16) * 0.01
    if cfg.num_patches:
        batch["patch_embeds"] = jnp.ones((B, cfg.num_patches, cfg.d_model),
                                         jnp.bfloat16) * 0.01
    if shape.kind != "train":
        batch.pop("labels")
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    run = make_run(arch)
    mesh = make_test_mesh()
    step, _ = steps.make_train_step(run, mesh)
    state = jax.jit(lambda k: steps.init_state(k, run))(jax.random.key(0))
    state2, metrics = step(state, make_batch(run))
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch}: loss={loss}"
    assert int(state2["step"]) == 1
    # params actually changed
    p0 = jax.tree.leaves(state["params"])[0] if False else None
    gn = float(metrics["grad_norm"])
    assert gn > 0, f"{arch}: zero grads"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_and_decode(arch):
    run = make_run(arch, kind="prefill", seq=32, batch=2)
    cfg = run.model
    mesh = make_test_mesh()
    params = api.model_init(jax.random.key(1), cfg)

    pre, _ = steps.make_prefill_step(run, mesh)
    logits, cache, enc = pre(params, make_batch(run))
    assert logits.shape[0] == 2
    assert np.isfinite(np.asarray(logits, np.float32)).all(), f"{arch} prefill NaN"

    drun = make_run(arch, kind="decode", seq=32, batch=2)
    dec, info = steps.make_decode_step(drun, mesh)
    dcache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                          info["cache_struct"])
    tok = jnp.ones((2, 1), jnp.int32)
    args = (params, dcache, tok, jnp.asarray(5, jnp.int32))
    if cfg.is_encoder_decoder:
        enc_out = jnp.ones((2, cfg.encoder_seq, cfg.d_model), jnp.bfloat16) * .01
        args = args + (enc_out,)
    lg, new_cache = dec(*args)
    assert lg.shape == (2, cfg.vocab_size)
    assert np.isfinite(np.asarray(lg, np.float32)).all(), f"{arch} decode NaN"
    # cache tree structure preserved
    jax.tree.map(lambda a, b: None, dcache, new_cache)


@pytest.mark.parametrize("arch", ARCHS)
def test_eval_deterministic_no_dropout(arch):
    """Eval mode (horn=None) must be deterministic and dropout-free."""
    run = make_run(arch)
    cfg = run.model
    from repro.core.steps import make_ctx
    ctx = make_ctx(cfg, None)
    params = api.model_init(jax.random.key(2), cfg)
    batch = make_batch(run)
    h1, _, _, _ = api.forward_hidden(params, batch, cfg, ctx, horn=None,
                                     mode="train", remat=False)
    h2, _, _, _ = api.forward_hidden(params, batch, cfg, ctx, horn=None,
                                     mode="train", remat=False)
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
