"""Traffic-trace record/replay, step profiler, and regression-gate
tests.

The acceptance-critical properties pinned here:
  * a trace file round-trips exactly (records + meta) and malformed
    headers are rejected loudly;
  * replaying the same trace twice on one engine is byte-identical:
    same token-stream SHA-256 AND identical virtual-clock TTFT/latency
    lists — and a fresh engine over the same weights reproduces the
    digest;
  * the step profiler attributes compiles to shape-bucket variants,
    reports ``cost_analysis`` FLOPs/bytes per variant, and flags a
    post-warmup recompile (the injected fault) as a ``recompile``
    anomaly alert that lands in the schema-validated Chrome trace
    export;
  * the engine config stamp (kv_dtype, pages_per_step, speculate_k,
    bank size, ...) reaches ``Engine.metrics()`` and the trace
    metadata;
  * the regression gate logic fails on a throughput collapse, a
    determinism break, and a post-warm compile — and passes a healthy
    run.
"""
import json
import os
import sys

import jax
import numpy as np
import pytest

from repro.configs.base import get_model_config, reduced
from repro.models import api
from repro.serving import Engine, EngineConfig
from repro.serving.observability import (RECOMPILE, Telemetry, TraceRecord,
                                         TraceRecorder, load_trace, replay,
                                         save_trace, stream_digest,
                                         validate_chrome_trace)

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "benchmarks"))
import regression  # noqa: E402  (benchmarks/ is not a package)


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced(get_model_config("qwen3-1.7b"), dtype="float32")
    return cfg, api.model_init(jax.random.key(0), cfg)


def make_engine(cfg, params, **over):
    kw = dict(num_slots=3, num_pages=64, page_size=8, max_prompt_len=32,
              max_new_tokens=6, token_budget=32, policy="on_demand",
              kv_dtype="float32", compute_dtype="float32")
    kw.update(over)
    return Engine(cfg, params, EngineConfig(**kw),
                  telemetry=Telemetry(timeline=True))


def small_trace(vocab, n=6, seed=9):
    rng = np.random.default_rng(seed)
    return [TraceRecord(arrival_s=0.004 * i,
                        prompt=list(rng.integers(1, vocab,
                                                 int(rng.integers(4, 12)))),
                        max_new_tokens=int(rng.integers(3, 7)))
            for i in range(n)]


# ---------------------------------------------------------------------------
# trace files
# ---------------------------------------------------------------------------
def test_trace_file_round_trip(tmp_path):
    rec = TraceRecorder(meta={"arch": "qwen3-1.7b", "note": "unit"})
    rec.add(0.25, [3, 1, 4], 5, slo_class="interactive", ensemble="mean",
            session="s0")
    rec.add(0.125, [2, 7], 3)
    path = tmp_path / "t.jsonl"
    assert rec.save(str(path)) == 2
    records, meta = load_trace(str(path))
    assert meta == {"arch": "qwen3-1.7b", "note": "unit"}
    # sorted by arrival on save
    assert [r.arrival_s for r in records] == [0.125, 0.25]
    assert records[1].prompt == [3, 1, 4]
    assert records[1].slo_class == "interactive"
    assert records[1].ensemble == "mean" and records[1].session == "s0"
    assert records[0].slo_class == "default" and records[0].ensemble is None


def test_trace_file_rejects_malformed(tmp_path):
    p = tmp_path / "bad.jsonl"
    p.write_text("")
    with pytest.raises(ValueError, match="empty"):
        load_trace(str(p))
    p.write_text('{"schema": "something-else", "version": 1}\n')
    with pytest.raises(ValueError, match="schema"):
        load_trace(str(p))
    p.write_text('{"schema": "horn-serving-trace", "version": 99}\n')
    with pytest.raises(ValueError, match="newer"):
        load_trace(str(p))
    p.write_text('{"schema": "horn-serving-trace", "version": 1}\n')
    with pytest.raises(ValueError, match="no records"):
        load_trace(str(p))


def test_stream_digest_is_order_canonical():
    a = stream_digest([(0, [1, 2]), (1, [3])])
    assert a == stream_digest([(0, [1, 2]), (1, [3])])
    assert a != stream_digest([(0, [1, 2]), (1, [4])])
    assert a != stream_digest([(1, [1, 2]), (0, [3])])


# ---------------------------------------------------------------------------
# record -> replay determinism
# ---------------------------------------------------------------------------
def test_replay_byte_identical_across_runs_and_engines(tiny):
    cfg, params = tiny
    records = small_trace(cfg.vocab_size)
    engine = make_engine(cfg, params)
    a = replay(engine, records)
    b = replay(engine, records)
    assert a.requests == b.requests == len(records)
    assert a.generated_tokens == b.generated_tokens > 0
    assert len(a.streams) == len(records)
    assert all(toks for _, toks in a.streams)
    # THE acceptance criterion: byte-identical greedy streams and
    # exactly reproducible virtual-clock TTFT/latency
    assert a.token_digest == b.token_digest
    assert a.ttft_s == b.ttft_s and a.latency_s == b.latency_s
    assert a.ticks == b.ticks and a.virtual_s == b.virtual_s
    # a FRESH engine over the same weights reproduces the digest too
    c = replay(make_engine(cfg, params), records)
    assert c.token_digest == a.token_digest


def test_replay_summary_uses_pooled_p10_not_wall(tiny):
    cfg, params = tiny
    engine = make_engine(cfg, params)
    records = small_trace(cfg.vocab_size)
    res = replay(engine, records)
    s = res.summary()
    assert s["tick_p10_wall_s"] == round(sorted(res.tick_wall_s)[
        int(0.10 * (len(res.tick_wall_s) - 1))], 6)
    assert s["decode_tok_s_p10"] == pytest.approx(
        res.generated_tokens / (s["tick_p10_wall_s"] * res.ticks), rel=1e-3)
    assert s["ttft_p99_s"] is not None and s["token_digest"]


# ---------------------------------------------------------------------------
# profiler: compile attribution, cost analysis, induced-fault alert
# ---------------------------------------------------------------------------
def test_profiler_attributes_compiles_and_costs(tiny):
    cfg, params = tiny
    engine = make_engine(cfg, params)
    records = small_trace(cfg.vocab_size)
    replay(engine, records)
    prof = engine.obs.profiler
    assert prof.compiles_total > 0                 # cold replay compiles
    assert prof.compiles_post_warm == 0            # ...but none post-warm
    cost = prof.cost_report()
    assert cost                                    # one entry per variant
    for label, entry in cost.items():
        assert label.startswith("unified_step[C=")
        assert entry["calls"] > 0
        assert entry["flops"] > 0 and entry["bytes_accessed"] > 0


def test_induced_recompile_alert_lands_in_trace_export(tiny, tmp_path):
    cfg, params = tiny
    engine = make_engine(cfg, params)
    records = small_trace(cfg.vocab_size)
    # warm until a replay mints no new compile cell
    for _ in range(4):
        replay(engine, records)
        if engine.obs.profiler.compiles_total == 0:
            break
    assert engine.obs.profiler.compiles_total == 0
    # the induced fault: flush the jit caches mid-stream
    jax.clear_caches()
    res = replay(engine, records, reset=False)
    prof = engine.obs.profiler
    assert prof.compiles_post_warm > 0
    kinds = {a["kind"] for a in res.alerts}
    assert RECOMPILE in kinds
    # the alert is in the schema-validated Chrome export, alongside the
    # engine-config metadata stamp
    path = tmp_path / "fault.trace.json"
    engine.obs.timeline.export(str(path))
    doc = json.loads(path.read_text())
    validate_chrome_trace(doc)
    alert_events = [e for e in doc["traceEvents"]
                    if e.get("name") == f"alert:{RECOMPILE}"]
    assert alert_events and alert_events[0]["ph"] == "i"
    assert "post-warmup recompile" in alert_events[0]["args"]["message"]
    compile_spans = [e for e in doc["traceEvents"]
                     if e.get("name") == "jit_compile"]
    assert compile_spans
    assert doc["otherData"]["engine_config"]["kv_dtype"] == "float32"


def test_engine_config_stamp_reaches_metrics_and_trace(tiny):
    cfg, params = tiny
    engine = make_engine(cfg, params, speculate_k=0)
    stamp = engine.obs.engine_config
    for key in ("kv_dtype", "compute_dtype", "pages_per_step",
                "speculate_k", "bank_size", "num_slots", "num_pages",
                "page_size", "token_budget", "policy"):
        assert key in stamp, key
    assert stamp["kv_dtype"] == "float32" and stamp["speculate_k"] == 0
    m = engine.metrics()
    assert m["config"] == stamp
    assert m["profiler"]["compiles_total"] == 0
    doc = engine.obs.timeline.to_chrome()
    meta_events = [e for e in doc["traceEvents"]
                   if e.get("ph") == "M"
                   and e.get("name") == "engine_config"]
    assert meta_events and meta_events[0]["args"]["kv_dtype"] == "float32"


# ---------------------------------------------------------------------------
# regression-gate logic (the full harness runs in CI, not tier-1)
# ---------------------------------------------------------------------------
def _healthy_result():
    return {
        "summary": {"token_digest": "abc", "decode_tok_s_p10": 1000.0,
                    "ttft_p99_s": 0.020, "latency_p99_s": 0.080,
                    "accept_rate": 0.6, "ticks": 50,
                    "generated_tokens": 200},
        "determinism": {"digest_a": "abc", "digest_b": "abc",
                        "byte_identical": True, "ttft_identical": True,
                        "latency_identical": True},
        "post_warm_compiles": 0,
    }


BASE = {"token_digest": "abc", "decode_tok_s_p10": 1000.0,
        "ttft_p99_s": 0.020, "accept_rate": 0.6}


def test_gate_passes_healthy_run():
    assert regression.evaluate_gates(_healthy_result(), BASE,
                                     regression.GATES) == []


def test_gate_fails_throughput_collapse_and_post_warm_compile():
    res = _healthy_result()
    res["summary"]["decode_tok_s_p10"] = 10.0      # the injected slowdown
    res["post_warm_compiles"] = 54
    fails = regression.evaluate_gates(res, BASE, regression.GATES)
    assert any("tok/s" in f for f in fails)
    assert any("post-warmup" in f for f in fails)


def test_gate_fails_determinism_break_and_ttft_regression():
    res = _healthy_result()
    res["determinism"]["digest_b"] = "zzz"
    res["determinism"]["byte_identical"] = False
    res["summary"]["ttft_p99_s"] = 0.025           # > 1.10x baseline
    fails = regression.evaluate_gates(res, BASE, regression.GATES)
    assert any("differ" in f for f in fails)
    assert any("TTFT" in f for f in fails)


def test_gate_accept_drop_fails_and_digest_drift_only_warns():
    res = _healthy_result()
    res["summary"]["accept_rate"] = 0.4
    res["summary"]["token_digest"] = "drifted"
    fails = regression.evaluate_gates(res, BASE, regression.GATES)
    assert any("accept rate" in f for f in fails)
    assert not any("digest" in f for f in fails)   # drift warns, not fails
    assert any("digest" in w for w in res["warnings"])


def test_baseline_entry_is_the_committed_shape():
    entry = regression.baseline_entry(_healthy_result())
    assert entry == {"token_digest": "abc", "decode_tok_s_p10": 1000.0,
                     "ttft_p99_s": 0.020, "latency_p99_s": 0.080,
                     "accept_rate": 0.6, "ticks": 50,
                     "generated_tokens": 200}


def test_pinned_traces_are_loadable_and_self_describing():
    for name in regression.TRACE_SPECS:
        path = os.path.join(regression.TRACES_DIR, f"{name}.jsonl")
        records, meta = load_trace(path)
        assert records, name
        assert meta["name"] == name
        # the meta must carry everything build_engine needs
        for key in ("arch", "slots", "pages", "page_size", "max_prompt",
                    "gen", "budget", "prefix_cache", "speculate_k",
                    "kv_dtype"):
            assert key in meta, (name, key)
        assert all(r.max_new_tokens <= meta["gen"] for r in records)
        assert all(len(r.prompt) <= meta["max_prompt"] for r in records)
