"""Distribution-layer tests: sharding rules, group sync, compression,
pipeline parallelism (multi-device via subprocess), elastic meshes."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.base import (SHAPES, TopologyConfig, get_model_config,
                                list_archs)
from repro.core import group_sync as gs
from repro.launch.mesh import sharding_rules
from repro.optim import compression as C


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------
class _FakeMesh:
    def __init__(self, sizes):
        self.axis_names = tuple(sizes)
        import numpy as _np
        self.devices = _np.empty(tuple(sizes.values()))


@pytest.mark.parametrize("arch", [a for a in list_archs() if a != "horn-mnist"])
def test_rules_divisibility(arch):
    """Every mapped axis must divide: the fallback chain never produces an
    invalid sharding for any arch on the production mesh."""
    cfg = get_model_config(arch)
    mesh = _FakeMesh({"data": 16, "model": 16})
    rules = sharding_rules(cfg, mesh)
    dims = {
        "heads": cfg.num_heads, "kv_heads": cfg.num_kv_heads,
        "head_dim": cfg.head_dim, "ffn": cfg.d_ff or 1,
        "embed": cfg.d_model, "vocab": cfg.vocab_size,
        "experts": cfg.num_experts or 1,
    }
    for axis, dim in dims.items():
        mapped = rules.get(axis)
        if mapped == "model":
            assert dim % 16 == 0, (arch, axis, dim)
        if mapped == "data":
            assert dim % 16 == 0, (arch, axis, dim)


def test_rules_degrade_on_odd_mesh():
    """Elastic scenario: a 12-way model axis makes 16 kv-heads unshardable ->
    replication, not an error."""
    cfg = get_model_config("gemma2-27b")
    rules = sharding_rules(cfg, _FakeMesh({"data": 14, "model": 12}))
    assert rules["kv_heads"] is None          # 16 % 12 != 0 -> replicate
    assert rules["ffn"] == "model"            # 36864 % 12 == 0 still TP


def test_batch_fallback_for_batch1_decode():
    cfg = get_model_config("mamba2-2.7b")
    rules = sharding_rules(cfg, _FakeMesh({"data": 16, "model": 16}),
                           SHAPES["long_500k"])
    assert rules["batch"] is None
    assert rules["seq"] == "data"


# ---------------------------------------------------------------------------
# group sync / local SGD
# ---------------------------------------------------------------------------
def test_local_sgd_merge_period():
    params = {"w": jnp.stack([jnp.full((3,), float(i)) for i in range(4)])}
    topo = TopologyConfig(kind="local_sgd", local_sgd_period=3)
    out, _ = gs.maybe_merge_local_sgd(params, jnp.asarray(0), topo)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(params["w"]))
    out, _ = gs.maybe_merge_local_sgd(params, jnp.asarray(2), topo)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.full((4, 3), 1.5))     # merged + broadcast


def test_group_drift_metric():
    same = {"w": jnp.ones((4, 3))}
    assert float(gs.group_drift(same)) == 0.0
    diff = {"w": jnp.stack([jnp.zeros(3), jnp.ones(3)])}
    assert float(gs.group_drift(diff)) > 0


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------
@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(1e-3, 1e3))
@settings(max_examples=25, deadline=None)
def test_int8_quantization_bounded_error(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(64,)) * scale, jnp.float32)
    q, s = C.quantize_int8(x)
    err = np.abs(np.asarray(C.dequantize_int8(q, s)) - np.asarray(x)).max()
    assert err <= float(s) * 0.5 + 1e-9       # half-ULP of the int8 grid


def test_error_feedback_converges():
    """With error feedback, the *accumulated* compressed signal tracks the
    accumulated true gradient (bias does not build up)."""
    rng = np.random.default_rng(0)
    true_sum = np.zeros(32, np.float32)
    sent_sum = np.zeros(32, np.float32)
    residual = jnp.zeros(32, jnp.float32)
    for t in range(200):
        g = jnp.asarray(rng.normal(size=32) * 0.01, jnp.float32)
        q, s, residual = C.ef_compress(g, residual)
        sent_sum += np.asarray(C.dequantize_int8(q, s))
        true_sum += np.asarray(g)
    # residual is bounded => sums differ by at most the residual
    np.testing.assert_allclose(sent_sum, true_sum,
                               atol=float(np.abs(residual).max()) + 1e-6)


# ---------------------------------------------------------------------------
# pipeline parallelism (needs >1 device -> subprocess with forced host count)
# ---------------------------------------------------------------------------
PIPELINE_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.runtime.pipeline import pipelined_apply

    S, L_per, M, mb, d = 4, 2, 8, 4, 16
    mesh = Mesh(np.array(jax.devices()[:S]), ("stage",))
    key = jax.random.key(0)
    Ws = jax.random.normal(key, (S, L_per, d, d)) * (d ** -0.5)
    x = jax.random.normal(jax.random.key(1), (M, mb, d))

    def block_fn(stage_w, h):
        for i in range(L_per):
            h = jnp.tanh(h @ stage_w[i])
        return h

    out = pipelined_apply(block_fn, Ws, x, mesh=mesh)
    # reference: apply all stages sequentially
    ref = x
    for s in range(S):
        ref = block_fn(Ws[s], ref)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)

    # grads flow through ppermute (reverse schedule for free)
    def loss_pipe(Ws):
        return jnp.sum(pipelined_apply(block_fn, Ws, x, mesh=mesh) ** 2)
    def loss_ref(Ws):
        h = x
        for s in range(S):
            h = block_fn(Ws[s], h)
        return jnp.sum(h ** 2)
    g1 = jax.grad(loss_pipe)(Ws)
    g2 = jax.grad(loss_ref)(Ws)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               atol=1e-4, rtol=1e-4)
    print("PIPELINE_OK")
""")


def test_pipeline_parallelism_4stage():
    r = subprocess.run([sys.executable, "-c", PIPELINE_PROG],
                       capture_output=True, text=True, timeout=300,
                       env={**__import__("os").environ,
                            "PYTHONPATH": "src"})
    assert "PIPELINE_OK" in r.stdout, r.stdout + r.stderr


def test_bubble_fraction():
    from repro.runtime.pipeline import bubble_fraction
    assert bubble_fraction(4, 16) == pytest.approx(3 / 19)
    assert bubble_fraction(1, 8) == 0
