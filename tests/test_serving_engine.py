"""Serving-subsystem tests: page-pool + scheduler invariants, the Pallas
paged-attention kernels (decode + chunk-append) vs their pure-jnp refs
(interpret mode, CPU), the continuous-batching engine reproducing
dense-cache greedy decode exactly through chunked prefill, and preemption
producing byte-identical output to an uninterrupted run.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.paged_attention.kernel import (paged_attention,
                                                  paged_chunk_attention)
from repro.kernels.paged_attention.ops import (paged_pool_append,
                                               paged_pool_update)
from repro.kernels.paged_attention.ref import (paged_attention_ref,
                                               paged_chunk_attention_ref)
from repro.serving.kv_cache import PagePool, PagePoolOOM
from repro.serving.scheduler import FCFSScheduler, Request


# ---------------------------------------------------------------------------
# page pool
# ---------------------------------------------------------------------------
def test_pool_alloc_free_roundtrip():
    pool = PagePool(num_pages=9, page_size=4)
    t1 = pool.alloc(1, 10)          # 3 pages
    t2 = pool.alloc(2, 4)           # 1 page
    pool.check_invariants()
    assert len(t1) == 3 and len(t2) == 1
    assert pool.used_pages == 4 and pool.free_pages == 4
    assert pool.utilization() == pytest.approx(0.5)
    assert 0 not in t1 + t2         # null page never handed out
    pool.free_seq(1)
    pool.check_invariants()
    assert pool.used_pages == 1
    pool.free_seq(2)
    assert pool.used_pages == 0 and pool.free_pages == 8


def test_pool_oom_leaves_allocation_intact():
    pool = PagePool(num_pages=5, page_size=4)   # 4 allocatable
    pool.alloc(1, 12)                           # 3 pages
    with pytest.raises(PagePoolOOM):
        pool.alloc(2, 8)                        # needs 2, only 1 free
    pool.check_invariants()
    # seq 2's failed attempt must not leak pages or stay registered
    assert pool.num_seqs == 1
    pool.alloc(2, 4)                            # retry at a size that fits
    pool.check_invariants()


def test_pool_ensure_grows_on_demand():
    pool = PagePool(num_pages=6, page_size=2)
    pool.alloc(7, 2)                            # 1 page covers 2 tokens
    assert len(pool.table(7)) == 1
    pool.ensure(7, 3)                           # crosses page boundary
    assert len(pool.table(7)) == 2
    pool.ensure(7, 3)                           # idempotent
    assert len(pool.table(7)) == 2
    pool.check_invariants()


def test_pool_double_alloc_rejected():
    pool = PagePool(num_pages=6, page_size=2)
    pool.alloc(1, 2)
    with pytest.raises(ValueError):
        pool.alloc(1, 2)
    with pytest.raises(ValueError):
        pool.alloc_pages(1, 1)


def test_pool_alloc_pages():
    pool = PagePool(num_pages=6, page_size=2)
    t = pool.alloc_pages(1, 3)
    assert len(t) == 3 and 0 not in t
    pool.check_invariants()
    with pytest.raises(PagePoolOOM):
        pool.alloc_pages(2, 3)                      # only 2 free
    assert pool.num_seqs == 1                       # failed alloc not registered
    pool.check_invariants()
    pool.free_seq(1)
    assert pool.free_pages == 5


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------
def _req(i, plen, max_new=4):
    return Request(id=i, prompt=np.zeros(plen, np.int32), max_new_tokens=max_new)


def test_scheduler_fcfs_admission_and_eviction():
    pool = PagePool(num_pages=64, page_size=4)
    sched = FCFSScheduler(2, pool, policy="reserve")
    for i in range(4):
        sched.submit(_req(i, plen=4))
    admitted = sched.admit(now=0.0)
    assert [r.id for r in admitted] == [0, 1]       # FCFS, slot-bounded
    assert not sched.admit(now=0.0)                 # no free slots
    # finish request 0 -> slot + pages free -> 2 joins mid-flight
    for t in range(4):
        sched.record_token(admitted[0].slot, 11, now=1.0)
    done = sched.evict_finished(now=2.0)
    assert [r.id for r in done] == [0]
    pool.check_invariants()
    joined = sched.admit(now=3.0)
    assert [r.id for r in joined] == [2]
    assert {r.id for r in sched.running.values()} == {1, 2}


def test_scheduler_no_head_of_line_bypass():
    pool = PagePool(num_pages=4, page_size=4)       # 3 allocatable pages
    sched = FCFSScheduler(4, pool, policy="reserve")
    sched.submit(_req(0, plen=12, max_new=4))       # needs 4 pages > 3 free
    sched.submit(_req(1, plen=1, max_new=1))        # would fit, must wait
    assert sched.admit(now=0.0) == []
    assert [r.id for r in sched.waiting] == [0, 1]


def test_scheduler_reserve_policy_never_grows():
    pool = PagePool(num_pages=16, page_size=2)
    sched = FCFSScheduler(1, pool, policy="reserve")
    req = _req(0, plen=3, max_new=5)
    sched.submit(req)
    sched.admit(now=0.0)
    before = len(pool.table(0))
    for _ in range(5):
        sched.record_token(req.slot, 1, now=0.0)
        pool.ensure(req.id, req.context_len)        # the engine's decode grow
    assert len(pool.table(0)) == before             # worst case pre-reserved


def test_scheduler_preempt_youngest_to_queue_head():
    pool = PagePool(num_pages=64, page_size=4)
    sched = FCFSScheduler(3, pool, policy="on_demand")
    for i in range(3):
        sched.submit(_req(i, plen=4))
    sched.admit(now=0.0)
    sched.submit(_req(3, plen=4))                   # waits behind the batch
    for slot, r in sched.running.items():           # give each some progress
        sched.record_token(slot, 7, now=1.0)
        r.prefill_pos = r.prompt_len
    victim = sched.preempt_youngest()
    assert victim.id == 2                           # youngest admission
    assert victim.slot is None and victim.prefill_pos == 0
    assert victim.num_preemptions == 1 and sched.preemptions == 1
    assert [r.id for r in sched.waiting] == [2, 3]  # head, before later work
    pool.check_invariants()
    # its pages are gone; its next chunked prefill must rebuild prompt+output
    assert victim.id not in pool._tables
    assert list(victim.kv_tokens) == list(victim.prompt)  # 1 tok: all pending
    sched.record_token(sched.admit(now=2.0)[0].slot, 8, now=2.0)
    # two running left -> preemption still possible; one left -> refused
    assert sched.preempt_youngest() is not None
    assert sched.preempt_youngest() is not None
    assert sched.preempt_youngest() is None         # sole survivor protected


def test_request_kv_tokens_carries_generated_prefix():
    req = _req(0, plen=3, max_new=8)
    req.out_tokens = [11, 12, 13]
    # the last generated token's KV is written by the decode step that
    # consumes it, so re-prefill covers prompt + out[:-1] only
    assert req.num_kv_tokens == 5
    assert list(req.kv_tokens) == [0, 0, 0, 11, 12]
    assert req.in_prefill                           # prefill_pos == 0 < 5
    req.prefill_pos = 5
    assert not req.in_prefill


# ---------------------------------------------------------------------------
# paged-attention kernel vs ref (Pallas interpret mode on CPU)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,H,KH,D,psize,maxp", [
    (2, 4, 4, 16, 8, 3),     # MHA
    (3, 4, 2, 32, 16, 4),    # GQA
    (1, 8, 1, 16, 8, 5),     # MQA
])
@pytest.mark.parametrize("variant", ["plain", "window", "softcap"])
def test_paged_attention_kernel_vs_ref(B, H, KH, D, psize, maxp, variant):
    # str hashes are randomized per interpreter; keep the data reproducible
    vid = {"plain": 1, "window": 2, "softcap": 3}[variant]
    rng = np.random.default_rng((B, H, KH, psize, vid))
    P = B * maxp + 1
    q = jnp.asarray(rng.normal(size=(B, H, D)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(P, psize, KH, D)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P, psize, KH, D)), jnp.float32)
    # each seq owns a disjoint page range; lengths straddle page boundaries
    bt = np.zeros((B, maxp), np.int32)
    lengths = np.zeros((B,), np.int32)
    for b in range(B):
        lengths[b] = int(rng.integers(1, maxp * psize + 1))
        npg = -(-int(lengths[b]) // psize)
        bt[b, :npg] = 1 + b * maxp + np.arange(npg)
    kw = {}
    if variant == "window":
        kw["window"] = psize + 3
    elif variant == "softcap":
        kw["softcap"] = 30.0
    out = paged_attention(q, kp, vp, jnp.asarray(bt), jnp.asarray(lengths),
                          scale=D ** -0.5, interpret=True, **kw)
    ref = paged_attention_ref(q, kp, vp, jnp.asarray(bt),
                              jnp.asarray(lengths), scale=D ** -0.5, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-5)


def test_paged_attention_empty_slot_emits_zeros():
    B, H, KH, D, psize, maxp = 2, 2, 2, 16, 8, 2
    rng = np.random.default_rng(0)
    kp = jnp.asarray(rng.normal(size=(5, psize, KH, D)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(B, H, D)), jnp.float32)
    bt = jnp.asarray([[1, 2], [0, 0]], jnp.int32)
    ln = jnp.asarray([11, 0], jnp.int32)
    out = paged_attention(q, kp, kp, bt, ln, scale=0.25, interpret=True)
    assert np.all(np.asarray(out)[1] == 0)
    assert np.all(np.isfinite(np.asarray(out)))


# ---------------------------------------------------------------------------
# chunk-append kernel vs ref (the unified serving step's workhorse)
# ---------------------------------------------------------------------------
PSIZE = 8


@pytest.mark.parametrize("B,H,KH,D,maxp", [
    (2, 4, 4, 16, 4),        # MHA
    (3, 4, 2, 32, 5),        # GQA
])
@pytest.mark.parametrize("C", [1, PSIZE, 3 * PSIZE - 1])
@pytest.mark.parametrize("variant", ["plain", "window", "softcap"])
def test_paged_chunk_attention_kernel_vs_ref(B, H, KH, D, maxp, C, variant):
    # str hashes are randomized per interpreter; keep the data reproducible
    vid = {"plain": 1, "window": 2, "softcap": 3}[variant]
    rng = np.random.default_rng((B, H, KH, C, vid))
    P = B * maxp + 1
    q = jnp.asarray(rng.normal(size=(B, C, H, D)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(P, PSIZE, KH, D)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P, PSIZE, KH, D)), jnp.float32)
    # each seq owns a disjoint page range; chunks start mid-page, straddle
    # page boundaries, and one row is a partial chunk (right-padded)
    bt = np.zeros((B, maxp), np.int32)
    starts = np.zeros((B,), np.int32)
    clens = np.zeros((B,), np.int32)
    for b in range(B):
        starts[b] = int(rng.integers(0, maxp * PSIZE - C + 1))
        clens[b] = C if b == 0 else int(rng.integers(0, C + 1))
        npg = max(1, -(-(int(starts[b]) + int(clens[b])) // PSIZE))
        bt[b, :npg] = 1 + b * maxp + np.arange(npg)
    kw = {}
    if variant == "window":
        kw["window"] = PSIZE + 3
    elif variant == "softcap":
        kw["softcap"] = 30.0
    args = (q, kp, vp, jnp.asarray(bt), jnp.asarray(starts),
            jnp.asarray(clens))
    out = paged_chunk_attention(*args, scale=D ** -0.5, interpret=True, **kw)
    ref = paged_chunk_attention_ref(*args, scale=D ** -0.5, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-5)
    # padding rows emit exact zeros (idle slots, partial chunks)
    for b in range(B):
        assert np.all(np.asarray(out)[b, clens[b]:] == 0)


def test_paged_chunk_attention_c1_bitwise_matches_decode():
    """Chunk width 1 IS the decode path — bit-for-bit, so the unified step's
    decode-only ticks are compatible with the classic paged-decode cell."""
    B, H, KH, D, psize, maxp = 3, 4, 2, 16, 8, 4
    rng = np.random.default_rng(7)
    P = B * maxp + 1
    q = jnp.asarray(rng.normal(size=(B, 1, H, D)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(P, psize, KH, D)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P, psize, KH, D)), jnp.float32)
    bt = np.zeros((B, maxp), np.int32)
    lengths = np.zeros((B,), np.int32)
    for b in range(B):
        lengths[b] = int(rng.integers(1, maxp * psize + 1))
        npg = -(-int(lengths[b]) // psize)
        bt[b, :npg] = 1 + b * maxp + np.arange(npg)
    for kw in ({}, {"window": 5}, {"softcap": 20.0}):
        dec = paged_attention(q[:, 0], kp, vp, jnp.asarray(bt),
                              jnp.asarray(lengths), scale=D ** -0.5,
                              interpret=True, **kw)
        chk = paged_chunk_attention(q, kp, vp, jnp.asarray(bt),
                                    jnp.asarray(lengths - 1),
                                    jnp.ones((B,), jnp.int32),
                                    scale=D ** -0.5, interpret=True, **kw)
        assert np.array_equal(np.asarray(dec), np.asarray(chk)[:, 0]), kw


def test_paged_pool_append_scatter():
    psize = 4
    pool = jnp.zeros((6, psize, 2, 8), jnp.float32)
    new = jnp.arange(2 * 5 * 2 * 8, dtype=jnp.float32).reshape(2, 5, 2, 8)
    bt = jnp.asarray([[1, 2, 0], [3, 4, 0]], jnp.int32)
    # seq 0: 5 valid tokens from position 2 (straddles pages 1 -> 2);
    # seq 1: 3 valid of 5 from position 0 (padding must hit the null page)
    out = np.asarray(paged_pool_append(pool, new, bt,
                                       jnp.asarray([2, 0], jnp.int32),
                                       jnp.asarray([5, 3], jnp.int32)))
    n = np.asarray(new)
    assert np.array_equal(out[1, 2], n[0, 0]) and \
        np.array_equal(out[1, 3], n[0, 1])
    assert np.array_equal(out[2, 0], n[0, 2]) and \
        np.array_equal(out[2, 2], n[0, 4])
    assert np.array_equal(out[3, :3], n[1, :3])
    assert np.all(out[3, 3] == 0) and np.all(out[4] == 0)  # padding nulled


def test_paged_pool_update_scatter():
    psize = 4
    pool = jnp.zeros((6, psize, 2, 8), jnp.float32)
    new = jnp.ones((3, 2, 8), jnp.float32) * jnp.asarray([1., 2., 3.])[:, None, None]
    bt = jnp.asarray([[1, 2], [3, 0], [0, 0]], jnp.int32)
    pos = jnp.asarray([5, 2, 0], jnp.int32)   # page 2 slot 1, page 3 slot 2, null
    out = np.asarray(paged_pool_update(pool, new, bt, pos))
    assert np.all(out[2, 1] == 1.0)           # seq 0 -> 2nd page, offset 1
    assert np.all(out[3, 2] == 2.0)           # seq 1 -> 1st page, offset 2
    assert np.all(out[0, 0] == 3.0)           # empty slot lands in null page
    assert out.sum() == (1.0 + 2.0 + 3.0) * 2 * 8


# ---------------------------------------------------------------------------
# engine end-to-end: continuous batching == dense-cache greedy decode
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch,budget", [
    ("qwen3-1.7b", 256),     # whole prompts fit one chunk
    ("qwen3-1.7b", 3),       # prompts split into 1-3 token chunks per tick
    ("gemma2-27b", 256),
    ("gemma2-27b", 5),
])
def test_engine_matches_dense_decode(arch, budget):
    # gemma2 covers the sliding-window (local) + softcap paged path; its
    # reduced window (16) is shorter than the 11-token+generated context of
    # the second prompt once pages are crossed.  The small budgets force the
    # unified tick to interleave prompt chunks with running decode tokens —
    # output must not depend on how prefill is chunked
    from repro.configs.base import get_model_config, reduced
    from repro.core.steps import make_ctx
    from repro.models import api
    from repro.models import transformer as T
    from repro.serving import Engine, EngineConfig

    cfg = reduced(get_model_config(arch))
    params = api.model_init(jax.random.key(0), cfg)
    ctx = make_ctx(cfg, None)
    max_new = 4

    def ref_generate(prompt):
        L = len(prompt)
        lg, cache, _ = api.prefill(
            params, {"tokens": jnp.asarray([prompt], jnp.int32)}, cfg, ctx)
        buf = T.init_cache(cfg, 1, L + max_new, dtype=jnp.float32)

        def splice(b, p):
            ax = b.ndim - 3
            pad = [(0, 0)] * b.ndim
            pad[ax] = (0, b.shape[ax] - p.shape[ax])
            return jnp.pad(p, pad).astype(b.dtype)

        cache = jax.tree.map(splice, buf, cache)
        toks = [int(jnp.argmax(lg[0]))]
        for i in range(max_new - 1):
            lg, cache = api.decode_step(
                params, cache, jnp.asarray([[toks[-1]]], jnp.int32),
                jnp.asarray(L + i, jnp.int32), cfg, ctx)
            toks.append(int(jnp.argmax(lg[0])))
        return toks

    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, (n,)).astype(np.int32)
               for n in (5, 11, 3)]
    refs = [ref_generate(list(map(int, p))) for p in prompts]

    # 2 slots, 3 requests: the third joins mid-flight after an eviction
    eng = Engine(cfg, params,
                 EngineConfig(num_slots=2, num_pages=32, page_size=8,
                              max_prompt_len=16, max_new_tokens=max_new,
                              token_budget=budget, policy="on_demand",
                              kv_dtype="float32", compute_dtype="float32"))
    for p in prompts:
        eng.submit(p, max_new)
    t = [0.0]

    def clk():
        t[0] += 1.0
        return t[0]

    fin = eng.run(clock=clk)
    got = {r.id: r.out_tokens for r in fin}
    for i, ref in enumerate(refs):
        assert got[i] == ref, f"request {i}: {got[i]} != {ref}"
    eng.pool.check_invariants()
    assert eng.pool.used_pages == 0                 # everything freed
    assert all(r.t_first_token is not None and r.t_done is not None
               for r in fin)


# ---------------------------------------------------------------------------
# preemption: evict mid-decode, re-admit, byte-identical output
# ---------------------------------------------------------------------------
def _run_engine(cfg, params, prompts, max_new, *, num_pages,
                temperature=0.0, budget=16):
    from repro.serving import Engine, EngineConfig

    eng = Engine(cfg, params,
                 EngineConfig(num_slots=2, num_pages=num_pages, page_size=4,
                              max_prompt_len=8, max_new_tokens=max_new,
                              token_budget=budget, temperature=temperature,
                              policy="on_demand", kv_dtype="float32",
                              compute_dtype="float32"))
    for p in prompts:
        eng.submit(p, max_new)
    t = [0.0]

    def clk():
        t[0] += 1.0
        return t[0]

    fin = eng.run(clock=clk)
    return eng, {r.id: list(r.out_tokens) for r in fin}


@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_preempted_request_output_is_byte_identical(temperature):
    """A sequence evicted mid-decode and re-admitted (KV recomputed through
    chunked prefill) must reproduce the uninterrupted run exactly — greedy
    and sampled: per-(request, step) fold_in keys survive preemption."""
    from repro.configs.base import get_model_config, reduced
    from repro.models import api

    cfg = reduced(get_model_config("qwen3-1.7b"))
    params = api.model_init(jax.random.key(0), cfg)
    prompts = [np.arange(1, 9, dtype=np.int32),
               np.arange(1, 6, dtype=np.int32)]
    # 6 allocatable pages: both admit (3 + 2 pages on_demand), then decode
    # growth runs the pool dry -> the younger sequence is preempted and
    # re-admitted after the older finishes
    tight, got = _run_engine(cfg, params, prompts, 8, num_pages=7,
                             temperature=temperature)
    assert tight.preemptions >= 1, "pool was never squeezed"
    tight.pool.check_invariants()
    assert tight.pool.used_pages == 0
    assert all(r.t_first_token is not None and r.t_done is not None
               for r in tight.sched.finished)

    roomy, want = _run_engine(cfg, params, prompts, 8, num_pages=64,
                              temperature=temperature)
    assert roomy.preemptions == 0
    assert got == want, f"preemption changed output: {got} != {want}"


def test_poisson_squeeze_completes_with_preemption():
    """The load that used to exit 2 with EngineOOM under on_demand now
    drains completely, recording preemptions instead."""
    from repro.configs.base import get_model_config, reduced
    from repro.launch.serve import make_requests
    from repro.models import api
    from repro.serving import Engine, EngineConfig

    cfg = reduced(get_model_config("qwen3-1.7b"))
    params = api.model_init(jax.random.key(0), cfg)
    eng = Engine(cfg, params,
                 EngineConfig(num_slots=4, num_pages=13, page_size=4,
                              max_prompt_len=16, max_new_tokens=12,
                              token_budget=16, policy="on_demand",
                              kv_dtype="float32", compute_dtype="float32"))
    rng = np.random.default_rng(0)
    reqs = make_requests(8, cfg.vocab_size, rng, max_prompt=16, gen=12)
    for _, prompt, g in reqs:
        eng.submit(prompt, g)
    fin = eng.run(clock=iter(np.arange(1e6)).__next__)
    assert len(fin) == 8                        # nothing lost, no EngineOOM
    assert eng.preemptions >= 1
    eng.pool.check_invariants()
    assert eng.pool.used_pages == 0


def test_engine_oom_only_when_unservable():
    """EngineOOM survives solely for genuinely unservable states: one
    sequence whose context can never fit the pool, even alone."""
    from repro.configs.base import get_model_config, reduced
    from repro.models import api
    from repro.serving import Engine, EngineConfig, EngineOOM

    cfg = reduced(get_model_config("qwen3-1.7b"))
    params = api.model_init(jax.random.key(0), cfg)
    # 3 allocatable pages of 4 tokens; the 8-token prompt admits on_demand
    # but needs 4 pages by token 13 — no other sequence to preempt
    eng = Engine(cfg, params,
                 EngineConfig(num_slots=2, num_pages=4, page_size=4,
                              max_prompt_len=8, max_new_tokens=8,
                              policy="on_demand", kv_dtype="float32",
                              compute_dtype="float32"))
    eng.submit(np.arange(1, 9, dtype=np.int32), 8)
    eng.submit(np.arange(1, 5, dtype=np.int32), 8)
    with pytest.raises(EngineOOM):
        for _ in range(64):
            eng.step(0.0)
    eng.pool.check_invariants()                     # state stays consistent


@pytest.mark.parametrize("speculate", [0, 3])
def test_engine_oom_leaks_no_pages_and_releases_router(speculate):
    """Every EngineOOM raise path must leave the engine consistent: the
    raising step allocates no pages it keeps (used_pages unchanged across
    it), pool invariants hold, and router loads still count exactly the
    live (unfinished) requests — finished work released, nothing double-
    released."""
    from repro.configs.base import HornConfig, get_model_config, reduced
    from repro.models import api
    from repro.serving import (Engine, EngineConfig, EngineOOM, ModelBank,
                               Router)

    cfg = reduced(get_model_config("qwen3-1.7b"), dtype="float32")
    params = api.model_init(jax.random.key(0), cfg)
    horn = HornConfig(enabled=True, keep_hidden=0.875, keep_input=1.0,
                      block_size=16)
    bank = ModelBank(cfg, horn, 2, seed=0)
    router = Router(2)
    draft = bank.draft_model(0, params) if speculate else None
    eng = Engine(cfg, params,
                 EngineConfig(num_slots=2, num_pages=4, page_size=4,
                              max_prompt_len=8, max_new_tokens=8,
                              policy="on_demand", kv_dtype="float32",
                              compute_dtype="float32",
                              speculate_k=speculate),
                 bank=bank, router=router, draft=draft)
    # 3 allocatable pages: the 8-token prompt admits on_demand but needs a
    # 4th page mid-decode with nothing left to preempt
    eng.submit(np.arange(1, 9, dtype=np.int32), 8)
    eng.submit(np.arange(1, 5, dtype=np.int32), 8)
    raised = False
    for _ in range(64):
        used = eng.pool.used_pages
        try:
            eng.step(0.0)
        except EngineOOM:
            raised = True
            assert eng.pool.used_pages == used, \
                "the raising step leaked pool pages"
            break
    assert raised, "pool was never exhausted"
    eng.pool.check_invariants()
    live = len(eng.sched.running) + len(eng.sched.waiting)
    assert sum(router.loads) == live, \
        f"router loads {router.loads} out of sync with {live} live requests"
    if speculate:
        eng.spec.pool.check_invariants()
        assert eng.spec.pool.num_seqs <= len(eng.sched.running)


def test_engine_oom_unadmittable_head_releases_and_keeps_pool():
    """The empty-batch raise path (a waiting head whose recompute stream
    can never fit, e.g. after preemption grew it): no allocation, loads
    consistent, and the raise repeats deterministically without corrupting
    state."""
    from repro.configs.base import get_model_config, reduced
    from repro.models import api
    from repro.serving import Engine, EngineConfig, EngineOOM

    cfg = reduced(get_model_config("qwen3-1.7b"), dtype="float32")
    params = api.model_init(jax.random.key(0), cfg)
    eng = Engine(cfg, params,
                 EngineConfig(num_slots=1, num_pages=6, page_size=4,
                              max_prompt_len=8, max_new_tokens=24,
                              policy="on_demand", kv_dtype="float32",
                              compute_dtype="float32"))
    a = eng.submit(np.arange(1, 5, dtype=np.int32), 2)
    b = eng.submit(np.arange(1, 9, dtype=np.int32), 24)   # waits: 1 slot
    # simulate the state preemption leaves behind: b evicted after 16
    # generated tokens, so its recompute stream (8 + 16 kv tokens) needs
    # more pages than the whole pool holds
    b.out_tokens.extend(range(100, 116))
    with pytest.raises(EngineOOM):
        for _ in range(64):
            eng.step(0.0)
    assert a.finished                      # earlier work completed cleanly
    assert eng.pool.used_pages == 0        # nothing admitted, nothing kept
    eng.pool.check_invariants()
    used = eng.pool.used_pages
    with pytest.raises(EngineOOM):         # deterministic, not corrupting
        eng.step(0.0)
    assert eng.pool.used_pages == used


def test_engine_rejects_infeasible_request():
    """A request that could never be admitted must fail at submit, not pin
    the FCFS head and spin the drive loop forever."""
    from repro.configs.base import get_model_config, reduced
    from repro.models import api
    from repro.serving import Engine, EngineConfig

    cfg = reduced(get_model_config("qwen3-1.7b"))
    params = api.model_init(jax.random.key(0), cfg)
    eng = Engine(cfg, params,
                 EngineConfig(num_slots=1, num_pages=3, page_size=4,
                              max_prompt_len=8, max_new_tokens=8,
                              policy="reserve"))
    with pytest.raises(ValueError, match="num_pages"):
        eng.submit(np.arange(1, 9, dtype=np.int32), 8)   # needs 4 > 2 pages
    assert not eng.sched.has_work()                      # nothing enqueued


def test_engine_rejects_unsupported_arch():
    from repro.configs.base import get_model_config, reduced
    from repro.serving import Engine, EngineConfig

    cfg = reduced(get_model_config("mamba2-2.7b"))
    with pytest.raises(ValueError):
        Engine(cfg, params=None, ecfg=EngineConfig())

# ---------------------------------------------------------------------------
# roofline-push kernel features: pages_per_step, dead-entry clamp, fused
# verify windows, int8 quantized pools
# ---------------------------------------------------------------------------
def _chunk_case(B, H, KH, D, maxp, C, seed, *, int8=False):
    """Disjoint-page chunk-attention fixture; int8 mode quantizes the pools
    with per-(page, kv-head) scales (compression.quantize_int8 layout)."""
    from repro.optim.compression import quantize_int8

    rng = np.random.default_rng(seed)
    P = B * maxp + 1
    q = jnp.asarray(rng.normal(size=(B, C, H, D)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(P, PSIZE, KH, D)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P, PSIZE, KH, D)), jnp.float32)
    bt = np.zeros((B, maxp), np.int32)
    starts = np.zeros((B,), np.int32)
    clens = np.zeros((B,), np.int32)
    for b in range(B):
        starts[b] = int(rng.integers(0, maxp * PSIZE - C + 1))
        clens[b] = C if b == 0 else int(rng.integers(0, C + 1))
        npg = max(1, -(-(int(starts[b]) + int(clens[b])) // PSIZE))
        bt[b, :npg] = 1 + b * maxp + np.arange(npg)
    scales = {}
    if int8:
        kq, ks = quantize_int8(kp, axis=(1, 3))
        vq, vs = quantize_int8(vp, axis=(1, 3))
        kp, vp = kq, vq
        scales = dict(k_scale=ks[:, 0, :, 0], v_scale=vs[:, 0, :, 0])
    return (q, kp, vp, jnp.asarray(bt), jnp.asarray(starts),
            jnp.asarray(clens)), scales


@pytest.mark.parametrize("variant", ["plain", "window", "softcap", "gqa"])
@pytest.mark.parametrize("C", [1, 4])
@pytest.mark.parametrize("dtype", ["float32", "int8"])
def test_paged_chunk_pages_per_step_sweep(variant, C, dtype):
    """The full kernel matrix: every (masking variant, chunk width, pool
    dtype) must be allclose to the pure-jnp ref, and pages_per_step in
    {2, 4} must be *bit-for-bit* identical to pages_per_step=1 (the grid
    restructure only changes DMA scheduling, never the op sequence)."""
    vid = {"plain": 1, "window": 2, "softcap": 3, "gqa": 4}[variant]
    H, KH = (4, 2) if variant == "gqa" else (4, 4)
    D, maxp = 16, 4
    args, scales = _chunk_case(2, H, KH, D, maxp, C, (vid, C),
                               int8=dtype == "int8")
    kw = dict(scales)
    if variant == "window":
        kw["window"] = PSIZE + 3
    elif variant == "softcap":
        kw["softcap"] = 30.0
    ref = paged_chunk_attention_ref(*args, scale=D ** -0.5, **kw)
    base = paged_chunk_attention(*args, scale=D ** -0.5, interpret=True,
                                 pages_per_step=1, **kw)
    np.testing.assert_allclose(np.asarray(base), np.asarray(ref),
                               atol=2e-5, rtol=1e-5)
    for pps in (2, 4):
        out = paged_chunk_attention(*args, scale=D ** -0.5, interpret=True,
                                    pages_per_step=pps, **kw)
        assert np.array_equal(np.asarray(out), np.asarray(base)), \
            f"pages_per_step={pps} changed bits ({variant}, C={C}, {dtype})"


def test_paged_decode_pages_per_step_bitwise():
    """Same invariant for the [B, H, D] decode kernel."""
    B, H, KH, D, psize, maxp = 3, 4, 2, 16, 8, 4
    rng = np.random.default_rng(11)
    P = B * maxp + 1
    q = jnp.asarray(rng.normal(size=(B, H, D)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(P, psize, KH, D)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P, psize, KH, D)), jnp.float32)
    bt = np.zeros((B, maxp), np.int32)
    lengths = np.asarray([psize * maxp, 5, psize + 1], np.int32)
    for b in range(B):
        npg = -(-int(lengths[b]) // psize)
        bt[b, :npg] = 1 + b * maxp + np.arange(npg)
    base = paged_attention(q, kp, vp, jnp.asarray(bt), jnp.asarray(lengths),
                           scale=D ** -0.5, interpret=True, pages_per_step=1)
    for pps in (2, 3, 4):
        out = paged_attention(q, kp, vp, jnp.asarray(bt),
                              jnp.asarray(lengths), scale=D ** -0.5,
                              interpret=True, pages_per_step=pps)
        assert np.array_equal(np.asarray(out), np.asarray(base)), pps


def test_dead_block_table_entries_never_gathered():
    """Block-table rows past a sequence's live length may hold stale or
    out-of-range page ids (freed pages, preemption leftovers) — the kernels
    must clamp their gathers to the null page, never dereference them.
    Poisoning every dead entry with an id far outside the pool must leave
    the output bit-for-bit unchanged."""
    B, H, KH, D, maxp, C = 2, 4, 2, 16, 4, 4
    args, _ = _chunk_case(B, H, KH, D, maxp, C, 23)
    q, kp, vp, bt, starts, clens = args
    bt_np = np.asarray(bt).copy()
    live = -(-(np.asarray(starts) + np.asarray(clens)) // PSIZE)
    poisoned = bt_np.copy()
    for b in range(B):
        poisoned[b, max(1, live[b]):] = 999_999      # way out of range
    assert not np.array_equal(poisoned, bt_np)
    for pps in (1, 2):
        clean = paged_chunk_attention(q, kp, vp, jnp.asarray(bt_np), starts,
                                      clens, scale=D ** -0.5, interpret=True,
                                      pages_per_step=pps)
        dirty = paged_chunk_attention(q, kp, vp, jnp.asarray(poisoned),
                                      starts, clens, scale=D ** -0.5,
                                      interpret=True, pages_per_step=pps)
        assert np.array_equal(np.asarray(clean), np.asarray(dirty)), pps
    # decode kernel too
    lengths = jnp.asarray(np.asarray(starts) + np.asarray(clens), jnp.int32)
    dec_c = paged_attention(q[:, 0], kp, vp, jnp.asarray(bt_np), lengths,
                            scale=D ** -0.5, interpret=True)
    dec_d = paged_attention(q[:, 0], kp, vp, jnp.asarray(poisoned), lengths,
                            scale=D ** -0.5, interpret=True)
    assert np.array_equal(np.asarray(dec_c), np.asarray(dec_d))


def test_fused_verify_window_matches_post_gather():
    """logit_index mode: the kernel's fused window output must equal
    gathering the same rows from the full-width output — bitwise — and the
    full-width output itself must be unchanged by the extra operand."""
    B, H, KH, D, maxp, C = 2, 4, 2, 16, 4, 6
    S_w = 3
    args, _ = _chunk_case(B, H, KH, D, maxp, C, 31)
    rng = np.random.default_rng(32)
    widx = jnp.asarray(rng.integers(0, C, size=(B, S_w)), jnp.int32)
    for pps in (1, 2):
        full = paged_chunk_attention(*args, scale=D ** -0.5, interpret=True,
                                     pages_per_step=pps)
        out, win = paged_chunk_attention(*args, scale=D ** -0.5,
                                         interpret=True, pages_per_step=pps,
                                         logit_index=widx)
        assert np.array_equal(np.asarray(out), np.asarray(full)), pps
        want = jnp.take_along_axis(full, widx[:, :, None, None], axis=1)
        assert np.array_equal(np.asarray(win), np.asarray(want)), pps
    # ref agrees with its own gather
    rout, rwin = paged_chunk_attention_ref(*args, scale=D ** -0.5,
                                           logit_index=widx)
    want = jnp.take_along_axis(rout, widx[:, :, None, None], axis=1)
    assert np.array_equal(np.asarray(rwin), np.asarray(want))


def test_paged_pool_append_quant_matches_f32_within_scale():
    """Quantize-on-append: the dequantized int8 pool must track the f32
    append within each touched page's quantization step (amax / 127), and
    untouched pages keep their bytes and scales."""
    from repro.kernels.paged_attention.ops import paged_pool_append_quant
    from repro.optim.compression import quantize_int8

    psize, KH, D = 4, 2, 8
    rng = np.random.default_rng(5)
    fpool = jnp.asarray(rng.normal(size=(8, psize, KH, D)), jnp.float32)
    qp, sc = quantize_int8(fpool, axis=(1, 3))
    sc = sc[:, 0, :, 0]
    new = jnp.asarray(rng.normal(size=(2, 5, KH, D)), jnp.float32)
    bt = jnp.asarray([[1, 2, 3], [4, 5, 0]], jnp.int32)
    starts = jnp.asarray([2, 0], jnp.int32)
    clens = jnp.asarray([5, 3], jnp.int32)
    fref = paged_pool_append(fpool, new, bt, starts, clens)
    qpool, qsc = paged_pool_append_quant(qp, sc, new, bt, starts, clens)
    deq = np.asarray(qpool, np.float32) * np.asarray(qsc)[:, None, :, None]
    fref = np.asarray(fref)
    for page in (1, 2, 3, 4, 5):                    # touched pages
        step = np.abs(fref[page]).max(axis=(0, 2)) / 127.0 + 1e-6
        err = np.abs(deq[page] - fref[page]).max(axis=(0, 2))
        assert (err <= step).all(), (page, err, step)
    for page in (6, 7):                             # untouched pages
        assert np.array_equal(np.asarray(qpool)[page], np.asarray(qp)[page])
        assert np.array_equal(np.asarray(qsc)[page], np.asarray(sc)[page])


def test_kv_page_bytes_int8_capacity_ratio():
    """The int8 pool (pages + f32 scale sidecars) must fit >= 1.9x the
    sequences of the bf16 pool at equal HBM for realistic page geometry."""
    from repro.serving.kv_cache import kv_page_bytes

    for psize, KH, D in [(16, 8, 128), (4, 2, 8), (16, 2, 64)]:
        bf16 = kv_page_bytes(psize, KH, D, "bfloat16")
        i8 = kv_page_bytes(psize, KH, D, "int8")
        assert bf16 == 2 * psize * KH * D * 2
        assert i8 == 2 * (psize * KH * D + KH * 4)
    # the >= 1.9x claim needs the sidecar amortized over a realistic page
    # (psize * head_dim >= ~128 elements per head); toy test pages sit lower
    for psize, KH, D in [(16, 8, 128), (16, 2, 64), (8, 4, 32), (4, 2, 64)]:
        ratio = (kv_page_bytes(psize, KH, D, "bfloat16")
                 / kv_page_bytes(psize, KH, D, "int8"))
        assert ratio >= 1.9, (psize, KH, D, ratio)


def test_engine_int8_and_pages_per_step():
    """End-to-end engine invariants of the new modes: pages_per_step > 1 is
    bit-identical to the classic engine; int8 pools build the 4-tuple
    (pages + scale sidecar) cache, stay pps-invariant, and greedy decode
    tracks the f32 engine within the documented divergence bound (exact
    token match is NOT expected: appends requantize whole pages)."""
    from repro.configs.base import get_model_config, reduced
    from repro.models import api
    from repro.serving import Engine, EngineConfig

    cfg = reduced(get_model_config("qwen3-1.7b"))
    params = api.model_init(jax.random.key(0), cfg)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 9, 3)]

    def run(**kw):
        ecfg = EngineConfig(num_slots=2, num_pages=32, page_size=4,
                            max_prompt_len=12, max_new_tokens=6,
                            token_budget=16, policy="on_demand",
                            kv_dtype=kw.pop("kv_dtype", "float32"),
                            compute_dtype="float32", **kw)
        eng = Engine(cfg, params, ecfg)
        for p in prompts:
            eng.submit(p, 6)
        fin = eng.run()
        assert eng.pool.used_pages == 0
        return eng, [list(r.out_tokens)
                     for r in sorted(fin, key=lambda r: r.id)]

    _, base = run()
    _, pps2 = run(pages_per_step=2)
    assert pps2 == base, "pages_per_step changed f32 engine output"
    q8_eng, q8 = run(kv_dtype="int8")
    _, q8pps = run(kv_dtype="int8", pages_per_step=4)
    assert q8pps == q8, "pages_per_step changed int8 engine output"
    leaves = jax.tree.leaves(q8_eng.cache)
    assert any(l.dtype == jnp.int8 for l in leaves), "no int8 pool leaf"
    assert any(l.dtype == jnp.float32 and l.ndim in (2, 3)
               for l in leaves), "no scale sidecar leaf"
    match = np.mean([np.mean([a == b for a, b in zip(x, y)])
                     for x, y in zip(base, q8)])
    # documented bound: an untrained random-weight model is the worst case
    # (near-uniform logits flip argmax on tiny perturbations); trained
    # checkpoints sit far above this
    assert match >= 0.5, f"int8 greedy diverged too far: {match:.2f}"
